//! On-disk shard snapshots: a versioned, checksummed binary container
//! for everything a Search Service needs to serve a shard — raw
//! publications, analyzed docs, BM25 statistics, and the CSR posting
//! arena byte-for-byte as built in memory.
//!
//! # File format (`*.gsnap`)
//!
//! ```text
//! magic    [8]  b"GAPSNAP1"
//! version  u32  SNAPSHOT_VERSION (little-endian, like every integer)
//! sections u32  section count
//! then per section:
//!   tag      [4]  ascii section name
//!   len      u64  payload byte length
//!   checksum u64  FNV-1a-64 over the payload
//!   payload  [len]
//! ```
//!
//! Sections (each must appear exactly once):
//!
//! * `META` — shard id, feature-space size
//! * `PUBS` — raw publications (id, title, abstract, authors, venue, year)
//! * `DOCS` — analyzed docs: per-field sparse (bucket, tf) + field lengths
//! * `STAT` — the shard's `ShardStats` contribution to global BM25 stats
//! * `INDX` — the raw CSR arena (offsets / docs / impacts / block
//!   offsets / block metadata), written in layout order so a load is a
//!   straight copy into the same `Vec`s the builder would have produced
//!
//! # Failure taxonomy
//!
//! Loading never panics on hostile input. Filesystem failures and
//! *corruption* (truncation anywhere, checksum mismatch) surface as
//! [`SearchError::Io`]; a file that simply is not a snapshot of this
//! version (bad magic, unknown version or section, structurally
//! inconsistent arrays, invariant-violating arena) surfaces as
//! [`SearchError::InvalidConfig`]. `tests/prop_snapshot.rs` bit-flips
//! and truncates real snapshots at every offset class to hold this line.

use std::path::Path;

use crate::corpus::Publication;
use crate::index::{BlockMeta, InvertedIndex, Shard, ShardDoc, ShardStats};
use crate::search::SearchError;
use crate::text::NUM_FIELDS;
use crate::util::json::Json;

/// Leading magic of every snapshot file.
pub const SNAPSHOT_MAGIC: [u8; 8] = *b"GAPSNAP1";

/// Current snapshot format version.
pub const SNAPSHOT_VERSION: u32 = 1;

/// File name of the deployment manifest inside a snapshot directory.
pub const MANIFEST_NAME: &str = "MANIFEST.json";

const SECTION_TAGS: [&[u8; 4]; 5] = [b"META", b"PUBS", b"DOCS", b"STAT", b"INDX"];

/// FNV-1a 64-bit — tiny, dependency-free, and plenty to catch the
/// random corruption (truncated copies, flipped bits) snapshots meet in
/// practice. Not cryptographic; snapshots are trusted-operator data.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn io_err(path: &Path, what: impl std::fmt::Display) -> SearchError {
    SearchError::Io { message: format!("{}: {what}", path.display()) }
}

fn format_err(path: &Path, what: impl std::fmt::Display) -> SearchError {
    SearchError::config(format!("{}: {what}", path.display()))
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

#[derive(Default)]
struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn u32s(&mut self, xs: &[u32]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.u32(x);
        }
    }
}

fn encode_meta(shard: &Shard) -> Vec<u8> {
    let mut w = Writer::default();
    w.u32(shard.id);
    w.u64(shard.features as u64);
    w.buf
}

fn encode_pubs(pubs: &[Publication]) -> Vec<u8> {
    let mut w = Writer::default();
    w.u64(pubs.len() as u64);
    for p in pubs {
        w.u64(p.id);
        w.str(&p.title);
        w.str(&p.abstract_text);
        w.str(&p.authors);
        w.str(&p.venue);
        w.u32(p.year);
    }
    w.buf
}

fn encode_docs(docs: &[ShardDoc]) -> Vec<u8> {
    let mut w = Writer::default();
    w.u64(docs.len() as u64);
    for d in docs {
        w.u64(d.global_id);
        for field in &d.field_tf {
            w.u64(field.len() as u64);
            for &(bucket, tf) in field {
                w.u32(bucket);
                w.f32(tf);
            }
        }
        for &len in &d.field_len {
            w.f32(len);
        }
    }
    w.buf
}

fn encode_stats(stats: &ShardStats) -> Vec<u8> {
    let mut w = Writer::default();
    w.u64(stats.num_docs);
    w.u64(stats.df.len() as u64);
    for &df in &stats.df {
        w.u64(df);
    }
    for &s in &stats.field_len_sum {
        w.f64(s);
    }
    w.buf
}

fn encode_index(ix: &InvertedIndex) -> Vec<u8> {
    let v = ix.raw_parts();
    let mut w = Writer::default();
    w.u32s(v.offsets);
    w.u32s(v.docs);
    w.u64(v.impacts.len() as u64);
    w.buf.extend_from_slice(v.impacts);
    w.u32s(v.block_offsets);
    w.u64(v.blocks.len() as u64);
    for b in v.blocks {
        w.u32(b.last_doc);
        w.u8(b.max_impact);
    }
    w.u32(v.num_docs);
    w.u32(v.block_size);
    w.buf
}

/// Serialize one shard into the snapshot container bytes.
pub fn encode_shard_snapshot(shard: &Shard) -> Vec<u8> {
    let sections: [(&[u8; 4], Vec<u8>); 5] = [
        (b"META", encode_meta(shard)),
        (b"PUBS", encode_pubs(&shard.pubs)),
        (b"DOCS", encode_docs(&shard.docs)),
        (b"STAT", encode_stats(&shard.stats)),
        (b"INDX", encode_index(&shard.inverted)),
    ];
    let mut out = Vec::with_capacity(
        16 + sections.iter().map(|(_, p)| p.len() + 20).sum::<usize>(),
    );
    out.extend_from_slice(&SNAPSHOT_MAGIC);
    out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
    out.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    for (tag, payload) in &sections {
        out.extend_from_slice(*tag);
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&fnv1a64(payload).to_le_bytes());
        out.extend_from_slice(payload);
    }
    out
}

/// Write one shard's snapshot file.
pub fn write_shard_snapshot(shard: &Shard, path: &Path) -> Result<(), SearchError> {
    std::fs::write(path, encode_shard_snapshot(shard)).map_err(|e| io_err(path, e))
}

// ---------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------

/// Bounds-checked little-endian reader over one section payload.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    path: &'a Path,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8], path: &'a Path) -> Reader<'a> {
        Reader { buf, pos: 0, path }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], SearchError> {
        if self.buf.len() - self.pos < n {
            return Err(io_err(self.path, "truncated snapshot section"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, SearchError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, SearchError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, SearchError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f32(&mut self) -> Result<f32, SearchError> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64, SearchError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A u64 count that must plausibly fit in the remaining bytes (each
    /// element is at least `elem_size` bytes) — rejects absurd lengths
    /// before any allocation is sized from them.
    fn count(&mut self, elem_size: usize) -> Result<usize, SearchError> {
        let n = self.u64()?;
        let remaining = (self.buf.len() - self.pos) as u64;
        match n.checked_mul(elem_size as u64) {
            Some(bytes) if bytes <= remaining => Ok(n as usize),
            _ => Err(io_err(self.path, "truncated snapshot section")),
        }
    }

    fn str(&mut self) -> Result<String, SearchError> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| format_err(self.path, "snapshot string is not UTF-8"))
    }

    fn u32s(&mut self) -> Result<Vec<u32>, SearchError> {
        let n = self.count(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    fn finish(self) -> Result<(), SearchError> {
        if self.pos != self.buf.len() {
            return Err(format_err(self.path, "trailing bytes in snapshot section"));
        }
        Ok(())
    }
}

fn decode_pubs(r: &mut Reader) -> Result<Vec<Publication>, SearchError> {
    // A publication encodes to >= 44 bytes (id + 4 empty strings + year).
    let n = r.count(44)?;
    let mut pubs = Vec::with_capacity(n);
    for _ in 0..n {
        let id = r.u64()?;
        let title = r.str()?;
        let abstract_text = r.str()?;
        let authors = r.str()?;
        let venue = r.str()?;
        let year = r.u32()?;
        pubs.push(Publication { id, title, abstract_text, authors, venue, year });
    }
    Ok(pubs)
}

fn decode_docs(r: &mut Reader) -> Result<Vec<ShardDoc>, SearchError> {
    // A doc encodes to >= 56 bytes (id + 4 empty fields + 4 lengths).
    let n = r.count(56)?;
    let mut docs = Vec::with_capacity(n);
    for _ in 0..n {
        let global_id = r.u64()?;
        let mut field_tf: [Vec<(u32, f32)>; NUM_FIELDS] = Default::default();
        for field in field_tf.iter_mut() {
            let pairs = r.count(8)?;
            field.reserve(pairs);
            for _ in 0..pairs {
                let bucket = r.u32()?;
                let tf = r.f32()?;
                field.push((bucket, tf));
            }
        }
        let mut field_len = [0.0f32; NUM_FIELDS];
        for len in field_len.iter_mut() {
            *len = r.f32()?;
        }
        docs.push(ShardDoc { global_id, field_tf, field_len });
    }
    Ok(docs)
}

fn decode_stats(r: &mut Reader) -> Result<ShardStats, SearchError> {
    let num_docs = r.u64()?;
    let n = r.count(8)?;
    let mut df = Vec::with_capacity(n);
    for _ in 0..n {
        df.push(r.u64()?);
    }
    let mut field_len_sum = [0.0f64; NUM_FIELDS];
    for s in field_len_sum.iter_mut() {
        *s = r.f64()?;
    }
    Ok(ShardStats { num_docs, df, field_len_sum })
}

fn decode_index(r: &mut Reader) -> Result<InvertedIndex, SearchError> {
    let path = r.path;
    let offsets = r.u32s()?;
    let docs = r.u32s()?;
    let n_impacts = r.count(1)?;
    let impacts = r.take(n_impacts)?.to_vec();
    let block_offsets = r.u32s()?;
    let n_blocks = r.count(5)?;
    let mut blocks = Vec::with_capacity(n_blocks);
    for _ in 0..n_blocks {
        let last_doc = r.u32()?;
        let max_impact = r.u8()?;
        blocks.push(BlockMeta { last_doc, max_impact });
    }
    let num_docs = r.u32()?;
    let block_size = r.u32()?;
    InvertedIndex::from_raw_parts(
        offsets,
        docs,
        impacts,
        block_offsets,
        blocks,
        num_docs,
        block_size,
    )
    .map_err(|e| format_err(path, format!("inconsistent posting arena: {e}")))
}

/// Decode a snapshot container from raw bytes (the single-read load
/// path; `path` only labels errors).
pub fn decode_shard_snapshot(bytes: &[u8], path: &Path) -> Result<Shard, SearchError> {
    let mut top = Reader::new(bytes, path);
    let magic = top.take(8).map_err(|_| format_err(path, "not a gaps snapshot (too short)"))?;
    if magic != SNAPSHOT_MAGIC {
        return Err(format_err(path, "not a gaps snapshot (bad magic)"));
    }
    let version = top.u32()?;
    if version != SNAPSHOT_VERSION {
        return Err(format_err(
            path,
            format!("unsupported snapshot version {version} (expected {SNAPSHOT_VERSION})"),
        ));
    }
    let n_sections = top.u32()? as usize;
    if n_sections != SECTION_TAGS.len() {
        return Err(format_err(path, format!("expected {} sections", SECTION_TAGS.len())));
    }

    let mut payloads: [Option<&[u8]>; 5] = [None; 5];
    for _ in 0..n_sections {
        let tag: [u8; 4] = top.take(4)?.try_into().expect("4 bytes");
        let len = top.count(1)?;
        let checksum = top.u64()?;
        let payload = top.take(len)?;
        if fnv1a64(payload) != checksum {
            return Err(io_err(
                path,
                format!("checksum mismatch in section {:?}", String::from_utf8_lossy(&tag)),
            ));
        }
        let slot = SECTION_TAGS
            .iter()
            .position(|t| **t == tag)
            .ok_or_else(|| {
                format_err(
                    path,
                    format!("unknown snapshot section {:?}", String::from_utf8_lossy(&tag)),
                )
            })?;
        if payloads[slot].replace(payload).is_some() {
            return Err(format_err(path, "duplicate snapshot section"));
        }
    }
    top.finish()?;
    let section = |slot: usize| payloads[slot].expect("all sections present");

    let mut meta = Reader::new(section(0), path);
    let id = meta.u32()?;
    let features = meta.u64()? as usize;
    meta.finish()?;

    let mut pr = Reader::new(section(1), path);
    let pubs = decode_pubs(&mut pr)?;
    pr.finish()?;

    let mut dr = Reader::new(section(2), path);
    let docs = decode_docs(&mut dr)?;
    dr.finish()?;

    let mut sr = Reader::new(section(3), path);
    let stats = decode_stats(&mut sr)?;
    sr.finish()?;

    let mut ir = Reader::new(section(4), path);
    let inverted = decode_index(&mut ir)?;
    ir.finish()?;

    // Cross-section invariants: the arrays must describe one shard.
    if pubs.len() != docs.len() {
        return Err(format_err(
            path,
            format!("{} publications vs {} analyzed docs", pubs.len(), docs.len()),
        ));
    }
    if inverted.num_docs() != docs.len() {
        return Err(format_err(
            path,
            format!("index covers {} docs, shard has {}", inverted.num_docs(), docs.len()),
        ));
    }
    if stats.df.len() != features || inverted.raw_parts().offsets.len() != features + 1 {
        return Err(format_err(path, "feature-space size mismatch between sections"));
    }
    if stats.num_docs != docs.len() as u64 {
        return Err(format_err(path, "stats doc count mismatch"));
    }
    Ok(Shard { id, features, pubs, docs, inverted, stats })
}

/// Load one shard from its snapshot file: a single `read` followed by
/// in-memory decoding and invariant re-validation.
pub fn read_shard_snapshot(path: &Path) -> Result<Shard, SearchError> {
    let bytes = std::fs::read(path).map_err(|e| io_err(path, e))?;
    decode_shard_snapshot(&bytes, path)
}

// ---------------------------------------------------------------------
// Deployment manifest
// ---------------------------------------------------------------------

/// One base data source in a deployment snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestSource {
    pub id: u32,
    pub doc_start: u64,
    pub doc_count: u64,
    /// Snapshot file name, relative to the manifest directory.
    pub file: String,
}

/// One sealed ingestion-overlay segment, in seal order.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestOverlay {
    /// Base source the overlay extends.
    pub source: u32,
    /// Snapshot file name, relative to the manifest directory.
    pub file: String,
}

/// `MANIFEST.json`: the directory-level description of a deployment
/// snapshot — which per-shard files exist, how global doc ids map onto
/// base sources, and the ingestion state (epoch, next id, overlays).
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotManifest {
    pub features: usize,
    pub epoch: u64,
    /// Docs covered by the base sources (excluding overlays).
    pub num_docs: u64,
    /// Next global id ingestion will assign.
    pub next_global_id: u64,
    pub sources: Vec<ManifestSource>,
    pub overlays: Vec<ManifestOverlay>,
}

impl SnapshotManifest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str("gaps-snapshot")),
            ("version", Json::from(SNAPSHOT_VERSION as i64)),
            ("features", Json::from(self.features as i64)),
            ("epoch", Json::from(self.epoch as i64)),
            ("num_docs", Json::from(self.num_docs as i64)),
            ("next_global_id", Json::from(self.next_global_id as i64)),
            (
                "sources",
                Json::Arr(
                    self.sources
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("id", Json::from(s.id as i64)),
                                ("doc_start", Json::from(s.doc_start as i64)),
                                ("doc_count", Json::from(s.doc_count as i64)),
                                ("file", Json::str(&s.file)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "overlays",
                Json::Arr(
                    self.overlays
                        .iter()
                        .map(|o| {
                            Json::obj(vec![
                                ("source", Json::from(o.source as i64)),
                                ("file", Json::str(&o.file)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SnapshotManifest, String> {
        let field = |k: &str| v.get(k).ok_or_else(|| format!("manifest missing '{k}'"));
        let int = |k: &str| -> Result<u64, String> {
            field(k)?
                .as_i64()
                .filter(|x| *x >= 0)
                .map(|x| x as u64)
                .ok_or_else(|| format!("manifest '{k}' must be a non-negative integer"))
        };
        if field("format")?.as_str() != Some("gaps-snapshot") {
            return Err("manifest 'format' is not 'gaps-snapshot'".into());
        }
        let version = int("version")?;
        if version != SNAPSHOT_VERSION as u64 {
            return Err(format!("unsupported manifest version {version}"));
        }
        let sources_json = field("sources")?
            .as_arr()
            .ok_or_else(|| "manifest 'sources' must be an array".to_string())?;
        let mut sources = Vec::with_capacity(sources_json.len());
        for s in sources_json {
            let get = |k: &str| -> Result<u64, String> {
                s.get(k)
                    .and_then(|x| x.as_i64())
                    .filter(|x| *x >= 0)
                    .map(|x| x as u64)
                    .ok_or_else(|| format!("manifest source missing '{k}'"))
            };
            sources.push(ManifestSource {
                id: get("id")? as u32,
                doc_start: get("doc_start")?,
                doc_count: get("doc_count")?,
                file: s
                    .get("file")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| "manifest source missing 'file'".to_string())?
                    .to_string(),
            });
        }
        let overlays_json = field("overlays")?
            .as_arr()
            .ok_or_else(|| "manifest 'overlays' must be an array".to_string())?;
        let mut overlays = Vec::with_capacity(overlays_json.len());
        for o in overlays_json {
            overlays.push(ManifestOverlay {
                source: o
                    .get("source")
                    .and_then(|x| x.as_i64())
                    .filter(|x| *x >= 0)
                    .ok_or_else(|| "manifest overlay missing 'source'".to_string())?
                    as u32,
                file: o
                    .get("file")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| "manifest overlay missing 'file'".to_string())?
                    .to_string(),
            });
        }
        Ok(SnapshotManifest {
            features: int("features")? as usize,
            epoch: int("epoch")?,
            num_docs: int("num_docs")?,
            next_global_id: int("next_global_id")?,
            sources,
            overlays,
        })
    }

    /// Write `MANIFEST.json` into the snapshot directory.
    pub fn write(&self, dir: &Path) -> Result<(), SearchError> {
        let path = dir.join(MANIFEST_NAME);
        std::fs::write(&path, self.to_json().to_string_pretty()).map_err(|e| io_err(&path, e))
    }

    /// Read `MANIFEST.json` from a snapshot directory.
    pub fn read(dir: &Path) -> Result<SnapshotManifest, SearchError> {
        let path = dir.join(MANIFEST_NAME);
        let text = std::fs::read_to_string(&path).map_err(|e| io_err(&path, e))?;
        let v = Json::parse(&text).map_err(|e| format_err(&path, e))?;
        SnapshotManifest::from_json(&v).map_err(|e| format_err(&path, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusGenerator, CorpusSpec};

    fn small_shard(n: u64) -> Shard {
        let spec = CorpusSpec { num_docs: n, vocab_size: 400, ..CorpusSpec::default() };
        let gen = CorpusGenerator::new(spec);
        Shard::build(3, gen.generate_range(0, n), 128)
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("gaps_test_snapshot");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip_preserves_everything() {
        let shard = small_shard(40);
        let path = tmp("rt.gsnap");
        write_shard_snapshot(&shard, &path).unwrap();
        let loaded = read_shard_snapshot(&path).unwrap();
        assert_eq!(loaded.id, shard.id);
        assert_eq!(loaded.features, shard.features);
        assert_eq!(loaded.pubs, shard.pubs);
        assert_eq!(loaded.docs, shard.docs);
        assert_eq!(loaded.stats, shard.stats);
        let (a, b) = (loaded.inverted.raw_parts(), shard.inverted.raw_parts());
        assert_eq!(a.offsets, b.offsets);
        assert_eq!(a.docs, b.docs);
        assert_eq!(a.impacts, b.impacts);
        assert_eq!(a.block_offsets, b.block_offsets);
        assert_eq!(a.blocks, b.blocks);
        assert_eq!(a.num_docs, b.num_docs);
        assert_eq!(a.block_size, b.block_size);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn bad_magic_and_version_are_typed() {
        let shard = small_shard(5);
        let mut bytes = encode_shard_snapshot(&shard);
        bytes[0] ^= 0xFF;
        let e = decode_shard_snapshot(&bytes, Path::new("x")).unwrap_err();
        assert_eq!(e.kind(), "invalid-config");
        let mut bytes2 = encode_shard_snapshot(&shard);
        bytes2[8] = 99; // version
        let e2 = decode_shard_snapshot(&bytes2, Path::new("x")).unwrap_err();
        assert_eq!(e2.kind(), "invalid-config");
    }

    #[test]
    fn payload_corruption_is_an_io_error() {
        let shard = small_shard(8);
        let bytes = encode_shard_snapshot(&shard);
        // Flip one byte deep inside the first payload (past tag+len+sum).
        let mut corrupt = bytes.clone();
        let i = 16 + 16 + 2;
        corrupt[i] ^= 0x01;
        let e = decode_shard_snapshot(&corrupt, Path::new("x")).unwrap_err();
        assert_eq!(e.kind(), "io", "checksum must catch a payload bit flip: {e}");
    }

    #[test]
    fn truncation_is_typed_never_a_panic() {
        let shard = small_shard(8);
        let bytes = encode_shard_snapshot(&shard);
        for cut in [0, 4, 8, 15, 16, 40, bytes.len() / 2, bytes.len() - 1] {
            let e = decode_shard_snapshot(&bytes[..cut], Path::new("x")).unwrap_err();
            assert!(
                matches!(e.kind(), "io" | "invalid-config"),
                "cut={cut}: unexpected kind {}",
                e.kind()
            );
        }
    }

    #[test]
    fn missing_file_is_io() {
        let e = read_shard_snapshot(Path::new("/nonexistent/x.gsnap")).unwrap_err();
        assert_eq!(e.kind(), "io");
    }

    #[test]
    fn manifest_round_trip() {
        let m = SnapshotManifest {
            features: 512,
            epoch: 7,
            num_docs: 1000,
            next_global_id: 1024,
            sources: vec![ManifestSource {
                id: 0,
                doc_start: 0,
                doc_count: 1000,
                file: "shard_0000.gsnap".into(),
            }],
            overlays: vec![ManifestOverlay { source: 0, file: "overlay_0000_0001.gsnap".into() }],
        };
        let back = SnapshotManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        let dir = std::env::temp_dir().join("gaps_test_snapshot_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        m.write(&dir).unwrap();
        assert_eq!(SnapshotManifest::read(&dir).unwrap(), m);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(SnapshotManifest::from_json(&Json::parse("{}").unwrap()).is_err());
        let wrong =
            Json::parse(r#"{"format":"zip","version":1,"features":1,"epoch":0,"num_docs":0,"next_global_id":0,"sources":[],"overlays":[]}"#)
                .unwrap();
        assert!(SnapshotManifest::from_json(&wrong).is_err());
    }
}
