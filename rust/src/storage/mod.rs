//! Persistence and live-update substrate: on-disk shard snapshots and
//! the segmented, additively updatable index.
//!
//! Two layers, both tombstone-free (publications are append-only —
//! academic records are never deleted in this corpus model):
//!
//! * [`snapshot`] — a versioned, checksummed binary container for one
//!   shard: raw publications, analyzed docs, BM25 statistics, and the
//!   CSR posting arena exactly as it sits in memory. Loading a snapshot
//!   is one `read` + bounds-checked decoding + invariant re-validation —
//!   no re-tokenization, no re-vectorization, no index rebuild — so a
//!   node restarts in milliseconds instead of re-analyzing its corpus.
//!   A [`snapshot::SnapshotManifest`] ties the per-shard files of a
//!   whole deployment together (base sources + ingestion overlays +
//!   the index epoch).
//! * [`segment`] — Lucene-style immutable segments: a
//!   [`SegmentedIndex`] answers retrieval across N sealed segments plus
//!   one in-memory mutable segment, merging per-segment top-k with the
//!   same bounded-heap ordering the monolithic index uses, so results
//!   are bit-identical to a single index over the same docs
//!   (property-tested against the `retrieve_reference` oracle in
//!   `tests/prop_segments.rs`). A tiered merge policy compacts sealed
//!   segments in the background; every seal/merge bumps the index
//!   epoch — the invalidation hook `/healthz`, `Explain`, and the
//!   serving layer's result cache (`serve::cache::ResultCache`) key
//!   on: cached top-k entries embed the epoch and are dropped wholesale
//!   when it moves.
//!
//! The coordinator builds on both: `GapsSystem::write_snapshot` /
//! `deploy_from_snapshot` persist and restore whole deployments, and
//! live ingestion (`POST /ingest`, `gaps ingest`) buffers publications
//! per source, seals them into immutable overlay shards at
//! `storage.seal_docs`, and compacts overlays with
//! [`segment::merge_shards`] at `storage.merge_fanout`.

pub mod segment;
pub mod snapshot;

pub use segment::{merge_shards, SegmentedIndex};
pub use snapshot::{
    read_shard_snapshot, write_shard_snapshot, ManifestOverlay, ManifestSource, SnapshotManifest,
    MANIFEST_NAME, SNAPSHOT_VERSION,
};
