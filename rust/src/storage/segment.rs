//! Lucene-style segmented index: N sealed immutable segments plus one
//! in-memory mutable segment, all over one contiguous doc array.
//!
//! The model is tombstone-free and purely additive (publications are
//! never deleted): documents append to the mutable tail, [`seal`]
//! freezes the tail into an immutable segment, and a tiered
//! [`merge_tiered`] policy compacts runs of similar-size sealed
//! segments back into one. Because every segment is an
//! [`InvertedIndex`] over an adjacent slice of the same doc array,
//! retrieval scores are per-document and independent of segmentation —
//! so per-segment top-k, merged under the monolithic ordering
//! (score desc, local id asc) and truncated, is **bit-identical** to a
//! single index over all docs. `tests/prop_segments.rs` pins this
//! against the `retrieve_reference` oracle across random segment
//! boundaries.
//!
//! Every seal and every merge bumps the [`epoch`](SegmentedIndex::epoch)
//! counter — the invalidation signal `/healthz` and `Explain` report
//! and the serving layer's result cache keys on: every cached top-k
//! entry embeds the epoch it was computed under, and the serve executor
//! drops the whole cache the moment an ingest round moves the epoch
//! (`serve::cache::ResultCache`), so a seal or merge can never leave
//! stale hits behind.
//!
//! [`seal`]: SegmentedIndex::seal
//! [`merge_tiered`]: SegmentedIndex::merge_tiered

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::index::{
    InvertedIndex, RetrievalCounters, RetrievalScratch, Shard, ShardDoc, ShardStats, BLOCK_SIZE,
};

/// One sealed segment: an immutable index over `docs[start..start+len]`.
#[derive(Debug, Clone)]
struct Segment {
    /// Offset of the segment's first doc in the owning doc array. A
    /// segment-local id `l` is the overall local id `start + l`.
    start: usize,
    index: InvertedIndex,
}

/// Segmented index over one logical shard's docs (module docs).
#[derive(Debug, Clone)]
pub struct SegmentedIndex {
    features: usize,
    block_size: usize,
    /// All docs in local-id order; segments cover adjacent slices.
    docs: Vec<ShardDoc>,
    /// Sealed segments in doc order: `sealed[i].start + len == sealed[i+1].start`.
    sealed: Vec<Segment>,
    /// Start of the mutable tail (`== docs.len()` when empty).
    mutable_start: usize,
    /// Index over `docs[mutable_start..]`; `None` iff the tail is empty.
    mutable: Option<InvertedIndex>,
    epoch: u64,
    seals: u64,
    merges: u64,
}

impl SegmentedIndex {
    pub fn new(features: usize) -> SegmentedIndex {
        SegmentedIndex::with_block_size(features, BLOCK_SIZE)
    }

    pub fn with_block_size(features: usize, block_size: usize) -> SegmentedIndex {
        assert!(block_size > 0, "block size must be positive");
        SegmentedIndex {
            features,
            block_size,
            docs: Vec::new(),
            sealed: Vec::new(),
            mutable_start: 0,
            mutable: None,
            epoch: 0,
            seals: 0,
            merges: 0,
        }
    }

    /// Append docs to the mutable segment. The mutable index is rebuilt
    /// eagerly (once per call, over the whole tail) so retrieval stays
    /// `&self`; ingestion batches amortize the rebuild.
    pub fn add_docs(&mut self, new_docs: Vec<ShardDoc>) {
        if new_docs.is_empty() {
            return;
        }
        self.docs.extend(new_docs);
        self.mutable = Some(InvertedIndex::build_with_block_size(
            &self.docs[self.mutable_start..],
            self.features,
            self.block_size,
        ));
    }

    /// Freeze the mutable tail into a sealed immutable segment. Returns
    /// false (and does not bump the epoch) when the tail is empty.
    pub fn seal(&mut self) -> bool {
        let Some(index) = self.mutable.take() else { return false };
        self.sealed.push(Segment { start: self.mutable_start, index });
        self.mutable_start = self.docs.len();
        self.seals += 1;
        self.epoch += 1;
        true
    }

    /// Tier of a segment for the merge policy: how many times `fanout`
    /// divides into its doc count. Segments born from equal seal
    /// thresholds share a tier; merging `fanout` of them promotes the
    /// result one tier up — classic tiered compaction.
    fn tier(len: usize, fanout: usize) -> u32 {
        let mut len = len.max(1);
        let mut t = 0;
        while len >= fanout {
            len /= fanout;
            t += 1;
        }
        t
    }

    /// Tiered background merge: while any `fanout` adjacent sealed
    /// segments share a size tier, rebuild them into one segment
    /// (exact — the merged index is `InvertedIndex::build` over the
    /// combined doc slice, so merged results stay bit-identical).
    /// Returns the number of merges performed; each bumps the epoch.
    pub fn merge_tiered(&mut self, fanout: usize) -> usize {
        if fanout < 2 {
            return 0;
        }
        let mut merged = 0;
        loop {
            let tiers: Vec<u32> =
                self.sealed.iter().map(|s| Self::tier(s.index.num_docs(), fanout)).collect();
            let run = (0..self.sealed.len().saturating_sub(fanout - 1))
                .find(|&i| tiers[i..i + fanout].iter().all(|&t| t == tiers[i]));
            let Some(i) = run else { break };
            let start = self.sealed[i].start;
            let end = start
                + self.sealed[i..i + fanout].iter().map(|s| s.index.num_docs()).sum::<usize>();
            let index = InvertedIndex::build_with_block_size(
                &self.docs[start..end],
                self.features,
                self.block_size,
            );
            self.sealed[i] = Segment { start, index };
            self.sealed.drain(i + 1..i + fanout);
            merged += 1;
            self.merges += 1;
            self.epoch += 1;
        }
        merged
    }

    /// Current index epoch (bumped on every seal and every merge).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Seals performed so far.
    pub fn seals(&self) -> u64 {
        self.seals
    }

    /// Merges performed so far.
    pub fn merges(&self) -> u64 {
        self.merges
    }

    /// Number of sealed segments.
    pub fn num_sealed(&self) -> usize {
        self.sealed.len()
    }

    /// Docs currently in the mutable (unsealed) tail.
    pub fn mutable_len(&self) -> usize {
        self.docs.len() - self.mutable_start
    }

    /// Total docs across every segment.
    pub fn num_docs(&self) -> usize {
        self.docs.len()
    }

    /// All docs in local-id order.
    pub fn docs(&self) -> &[ShardDoc] {
        &self.docs
    }

    /// Segment views in doc order: sealed first, then the mutable tail.
    fn segments(&self) -> impl Iterator<Item = (usize, &InvertedIndex)> + '_ {
        self.sealed
            .iter()
            .map(|s| (s.start, &s.index))
            .chain(self.mutable.iter().map(move |ix| (self.mutable_start, ix)))
    }

    /// OR-retrieve the top `max_candidates` candidates across every
    /// segment: per-segment block-max WAND, merged through the same
    /// bounded min-heap ordering the monolithic index uses. Returns
    /// (local_id, score) sorted score desc then id asc — bit-identical
    /// to one `InvertedIndex` over all docs — plus the aggregated work
    /// counters (posting totals sum exactly; block geometry may differ
    /// from the monolithic layout).
    pub fn retrieve_into(
        &self,
        buckets: &[u32],
        max_candidates: usize,
        scratch: &mut RetrievalScratch,
    ) -> (Vec<(u32, u32)>, RetrievalCounters) {
        let mut counters = RetrievalCounters::default();
        let mut heap: BinaryHeap<Reverse<(u32, Reverse<u32>)>> =
            BinaryHeap::with_capacity(max_candidates + 1);
        for (start, index) in self.segments() {
            index.retrieve_into(buckets, max_candidates, scratch);
            counters.merge(scratch.counters());
            for &(lid, score) in scratch.hits() {
                let key = Reverse((score, Reverse(start as u32 + lid)));
                if heap.len() < max_candidates {
                    heap.push(key);
                } else if let Some(worst) = heap.peek() {
                    if key < *worst {
                        heap.pop();
                        heap.push(key);
                    }
                }
            }
        }
        let mut out: Vec<(u32, u32)> =
            heap.into_iter().map(|Reverse((s, Reverse(d)))| (d, s)).collect();
        out.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        (out, counters)
    }

    /// AND-retrieve up to `limit` docs containing all buckets, in
    /// increasing local id: segments are visited in doc order with the
    /// remaining-limit budget, so the result equals the monolithic
    /// `retrieve_all` prefix.
    pub fn retrieve_all(&self, buckets: &[u32], limit: usize) -> (Vec<u32>, RetrievalCounters) {
        let mut counters = RetrievalCounters::default();
        let mut out = Vec::new();
        for (start, index) in self.segments() {
            if out.len() >= limit {
                break;
            }
            let mut seg_counters = RetrievalCounters::default();
            let hits = index.retrieve_all_counted(buckets, limit - out.len(), &mut seg_counters);
            counters.merge(&seg_counters);
            out.extend(hits.into_iter().map(|lid| start as u32 + lid));
        }
        (out, counters)
    }
}

/// Compact several shards (immutable overlay segments of one data
/// source) into one: concatenate raw + analyzed docs in segment order,
/// merge the additive statistics, and rebuild the inverted index from
/// the already-analyzed docs — no re-tokenization. The resulting shard
/// ranks identically to serving the parts separately and merging
/// top-k, which is what makes background compaction invisible to
/// queries.
pub fn merge_shards(id: u32, parts: Vec<Shard>) -> Shard {
    assert!(!parts.is_empty(), "merge_shards needs at least one part");
    let features = parts[0].features;
    let mut pubs = Vec::with_capacity(parts.iter().map(|p| p.pubs.len()).sum());
    let mut docs = Vec::with_capacity(parts.iter().map(|p| p.docs.len()).sum());
    let mut stats = ShardStats::empty(features);
    for part in parts {
        assert_eq!(part.features, features, "feature space mismatch in merge");
        stats.merge(&part.stats);
        pubs.extend(part.pubs);
        docs.extend(part.docs);
    }
    let inverted = InvertedIndex::build(&docs, features);
    Shard { id, features, pubs, docs, inverted, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusGenerator, CorpusSpec};

    fn corpus_docs(n: u64) -> Vec<ShardDoc> {
        let spec = CorpusSpec { num_docs: n, vocab_size: 300, ..CorpusSpec::default() };
        let gen = CorpusGenerator::new(spec);
        Shard::build(0, gen.generate_range(0, n), 64).docs
    }

    fn monolith(docs: &[ShardDoc]) -> InvertedIndex {
        InvertedIndex::build(docs, 64)
    }

    #[test]
    fn empty_index_answers_empty() {
        let seg = SegmentedIndex::new(64);
        let mut scratch = RetrievalScratch::new();
        let (hits, counters) = seg.retrieve_into(&[1, 2, 3], 10, &mut scratch);
        assert!(hits.is_empty());
        assert_eq!(counters, RetrievalCounters::default());
        assert_eq!(seg.retrieve_all(&[1], 10).0, Vec::<u32>::new());
        assert_eq!(seg.epoch(), 0);
    }

    #[test]
    fn segmented_matches_monolithic_and_seal_bumps_epoch() {
        let docs = corpus_docs(120);
        let mono = monolith(&docs);
        let mut seg = SegmentedIndex::new(64);
        seg.add_docs(docs[..50].to_vec());
        assert!(seg.seal());
        assert_eq!(seg.epoch(), 1);
        seg.add_docs(docs[50..90].to_vec());
        assert!(seg.seal());
        seg.add_docs(docs[90..].to_vec()); // stays mutable
        assert_eq!(seg.num_sealed(), 2);
        assert_eq!(seg.mutable_len(), 30);

        let mut scratch = RetrievalScratch::new();
        for query in [vec![0u32, 1, 2], vec![5, 9], vec![63]] {
            for k in [1usize, 5, 40, 200] {
                let (hits, counters) = seg.retrieve_into(&query, k, &mut scratch);
                assert_eq!(hits, mono.retrieve(&query, k), "query {query:?} k={k}");
                assert!(counters.postings_touched <= counters.postings_total);
            }
            let (all, _) = seg.retrieve_all(&query, 500);
            assert_eq!(all, mono.retrieve_all(&query, 500), "AND {query:?}");
        }
    }

    #[test]
    fn sealing_empty_tail_is_a_noop() {
        let mut seg = SegmentedIndex::new(8);
        assert!(!seg.seal());
        assert_eq!(seg.epoch(), 0);
        seg.add_docs(corpus_docs(5));
        assert!(seg.seal());
        assert!(!seg.seal(), "second seal with empty tail must not fire");
        assert_eq!(seg.epoch(), 1);
    }

    #[test]
    fn tiered_merge_compacts_and_preserves_results() {
        let docs = corpus_docs(160);
        let mono = monolith(&docs);
        let mut seg = SegmentedIndex::new(64);
        for chunk in docs.chunks(20) {
            seg.add_docs(chunk.to_vec());
            seg.seal();
        }
        assert_eq!(seg.num_sealed(), 8);
        let epoch_before = seg.epoch();
        let merges = seg.merge_tiered(4);
        assert!(merges >= 2, "8 equal segments at fanout 4 merge at least twice");
        assert!(seg.num_sealed() < 8);
        assert_eq!(seg.epoch(), epoch_before + merges as u64);
        assert_eq!(seg.merges(), merges as u64);

        let mut scratch = RetrievalScratch::new();
        let (hits, _) = seg.retrieve_into(&[0, 1, 2, 3], 25, &mut scratch);
        assert_eq!(hits, mono.retrieve(&[0, 1, 2, 3], 25));
        // Segment starts must still partition the doc array.
        let (all, _) = seg.retrieve_all(&[0], seg.num_docs());
        assert_eq!(all, mono.retrieve_all(&[0], seg.num_docs()));
    }

    #[test]
    fn merge_fanout_below_two_is_disabled() {
        let mut seg = SegmentedIndex::new(8);
        for chunk in corpus_docs(40).chunks(10) {
            seg.add_docs(chunk.to_vec());
            seg.seal();
        }
        assert_eq!(seg.merge_tiered(0), 0);
        assert_eq!(seg.merge_tiered(1), 0);
        assert_eq!(seg.num_sealed(), 4);
    }

    #[test]
    fn merge_shards_concatenates_and_rebuilds() {
        let spec = CorpusSpec { num_docs: 60, vocab_size: 300, ..CorpusSpec::default() };
        let gen = CorpusGenerator::new(spec);
        let a = Shard::build(7, gen.generate_range(0, 40), 64);
        let b = Shard::build(7, gen.generate_range(40, 20), 64);
        let whole = Shard::build(7, gen.generate_range(0, 60), 64);
        let merged = merge_shards(7, vec![a, b]);
        assert_eq!(merged.pubs, whole.pubs);
        assert_eq!(merged.docs, whole.docs);
        assert_eq!(merged.stats, whole.stats);
        assert_eq!(
            merged.inverted.retrieve(&[1, 2, 3], 10),
            whole.inverted.retrieve(&[1, 2, 3], 10)
        );
    }
}
