//! Experiment metrics + the node-sweep driver behind Figures 3/4/5.
//!
//! The paper evaluates three metrics over a node sweep:
//!
//! * **response time** — end-to-end seconds per query (Fig 3);
//! * **speedup** — `T(serial) / T(n nodes)` (Fig 4);
//! * **efficiency** — `speedup / n` (Fig 5).
//!
//! [`run_node_sweep`] deploys GAPS and the traditional baseline over the
//! *same* data at each node count, runs the same query mix through both,
//! and returns one [`SweepPoint`] per node count. The benches print these
//! as the paper's figure series; examples reuse the same driver.

use std::sync::Arc;

use anyhow::Result;

use crate::baseline::TraditionalSearch;
use crate::config::GapsConfig;
use crate::coordinator::{CorpusData, Deployment, GapsSystem};
use crate::search::SearchRequest;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Measured series for one system at one node count.
#[derive(Debug, Clone)]
pub struct SystemPoint {
    /// Mean response time over the query mix (seconds).
    pub response_s: f64,
    /// p50 / p99 response times.
    pub p50_s: f64,
    pub p99_s: f64,
    /// Mean split of the critical path.
    pub work_s: f64,
    pub net_s: f64,
    pub overhead_s: f64,
}

/// One sweep point: both systems at `nodes`.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub nodes: usize,
    pub docs: u64,
    pub gaps: SystemPoint,
    pub traditional: SystemPoint,
}

impl SweepPoint {
    /// Speedup relative to the provided serial (1-node) response time.
    pub fn speedup(&self, serial_response_s: f64, system: System) -> f64 {
        serial_response_s / self.system(system).response_s
    }

    /// Efficiency = speedup / nodes.
    pub fn efficiency(&self, serial_response_s: f64, system: System) -> f64 {
        self.speedup(serial_response_s, system) / self.nodes as f64
    }

    fn system(&self, s: System) -> &SystemPoint {
        match s {
            System::Gaps => &self.gaps,
            System::Traditional => &self.traditional,
        }
    }
}

/// System selector for metric lookups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    Gaps,
    Traditional,
}

/// Complete sweep result.
#[derive(Debug, Clone)]
pub struct Sweep {
    pub points: Vec<SweepPoint>,
    /// Query mix used at every point (identical across points/systems).
    pub queries: Vec<String>,
}

impl Sweep {
    /// Serial (1-node) reference for speedup, per system. Uses the first
    /// point if it is a 1-node point, else extrapolates from the smallest.
    pub fn serial_response_s(&self, system: System) -> f64 {
        let first = &self.points[0];
        match system {
            System::Gaps => first.gaps.response_s * first.nodes as f64,
            System::Traditional => first.traditional.response_s * first.nodes as f64,
        }
    }
}

/// Sample a deterministic query mix from the corpus topics (plus a couple
/// of multivariate queries, mirroring the USI's two search types).
pub fn sample_queries(dep: &Deployment, n: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut q = dep.generator().sample_query(&mut rng);
        if i % 5 == 4 {
            // Every 5th query is multivariate (year-ranged).
            let lo = 1998 + rng.below(10) as u32;
            q.push_str(&format!(" year:{lo}..{}", lo + 6));
        }
        out.push(q);
    }
    out
}

/// Number of measured passes per point; per-query the *fastest* pass is
/// kept. The searched work is deterministic, so the minimum is the
/// noise-free estimate on a busy 1-core host (OS jitter only ever adds
/// time); fabric costs are accounted, not measured, and identical across
/// passes.
const MEASURE_PASSES: usize = 3;

/// Aggregate per-query best timelines into a SystemPoint.
fn aggregate(best: &[crate::util::clock::TaskTimeline]) -> SystemPoint {
    let mut resp = Summary::new();
    let (mut work, mut net, mut overhead) = (Summary::new(), Summary::new(), Summary::new());
    for t in best {
        resp.add(t.total_s());
        work.add(t.work_s);
        net.add(t.net_s);
        overhead.add(t.overhead_s);
    }
    SystemPoint {
        response_s: resp.mean(),
        p50_s: resp.p50(),
        p99_s: resp.p99(),
        work_s: work.mean(),
        net_s: net.mean(),
        overhead_s: overhead.mean(),
    }
}

/// Run the query mix through one GAPS system (typed requests, one per
/// query), collecting stats.
pub fn measure_gaps(sys: &mut GapsSystem, queries: &[String]) -> Result<SystemPoint> {
    let requests: Vec<SearchRequest> =
        queries.iter().map(|q| SearchRequest::new(q.clone())).collect();
    let mut best = vec![crate::util::clock::TaskTimeline::default(); queries.len()];
    for pass in 0..MEASURE_PASSES {
        for (i, req) in requests.iter().enumerate() {
            let r = sys.search_request(req)?;
            if pass == 0 || r.response_s() < best[i].total_s() {
                best[i] = r.timeline;
            }
        }
    }
    Ok(aggregate(&best))
}

/// Run the query mix through the traditional baseline.
pub fn measure_traditional(sys: &mut TraditionalSearch, queries: &[String]) -> Result<SystemPoint> {
    let requests: Vec<SearchRequest> =
        queries.iter().map(|q| SearchRequest::new(q.clone())).collect();
    let mut best = vec![crate::util::clock::TaskTimeline::default(); queries.len()];
    for pass in 0..MEASURE_PASSES {
        for (i, req) in requests.iter().enumerate() {
            let r = sys.search_request(req)?;
            if pass == 0 || r.response_s() < best[i].total_s() {
                best[i] = r.timeline;
            }
        }
    }
    Ok(aggregate(&best))
}

/// The figure driver: sweep `node_counts`, same corpus + query mix, both
/// systems on identical deployments. GAPS runs one warmup pass so its
/// perf-history planner has data (the paper's system is long-running).
pub fn run_node_sweep(cfg: &GapsConfig, node_counts: &[usize]) -> Result<Sweep> {
    // Sweeps measure with serial dispatch: the accounted timelines
    // already model node-level parallelism (slowest branch dominates a
    // barrier), and running jobs concurrently on the host would let
    // cross-thread contention inflate each job's measured work_s and
    // skew the figure curves. Real wall-clock fan-out speedup is
    // measured separately (benches/fig3_response_time.rs bench_fanout).
    let mut cfg = cfg.clone();
    cfg.search.workers = 1;
    let cfg = &cfg;
    let mut points = Vec::with_capacity(node_counts.len());
    let mut queries_out = Vec::new();
    // The analyzed corpus does not depend on node count (sources are
    // fixed); build it once and re-place it per sweep point.
    let max_n = node_counts.iter().copied().max().unwrap_or(1);
    let num_sources = cfg.workload.sub_shards.max(max_n).max(1) as u64;
    let corpus = Arc::new(CorpusData::build(cfg, num_sources)?);
    for &n in node_counts {
        let dep = Arc::new(Deployment::assemble(cfg, n, Arc::clone(&corpus))?);
        let queries = sample_queries(&dep, cfg.workload.num_queries, cfg.workload.seed ^ 0x51);
        let mut gaps = GapsSystem::from_deployment(cfg.clone(), Arc::clone(&dep))?;
        // Warmup (not measured): one full pass per system — populates the
        // GAPS perf DB and warms every artifact shape / allocator path so
        // measured passes are stable. Both systems get the same treatment.
        for q in &queries {
            gaps.search(q)?;
        }
        let gaps_point = measure_gaps(&mut gaps, &queries)?;
        let mut trad = TraditionalSearch::from_deployment(cfg.clone(), Arc::clone(&dep))?;
        for q in &queries {
            trad.search(q)?;
        }
        let trad_point = measure_traditional(&mut trad, &queries)?;
        points.push(SweepPoint {
            nodes: n,
            docs: cfg.workload.num_docs,
            gaps: gaps_point,
            traditional: trad_point,
        });
        queries_out = queries;
    }
    Ok(Sweep { points, queries: queries_out })
}

// ------------------------------------------------------------ sweep cache

impl SystemPoint {
    fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("response_s", Json::from(self.response_s)),
            ("p50_s", Json::from(self.p50_s)),
            ("p99_s", Json::from(self.p99_s)),
            ("work_s", Json::from(self.work_s)),
            ("net_s", Json::from(self.net_s)),
            ("overhead_s", Json::from(self.overhead_s)),
        ])
    }

    fn from_json(v: &crate::util::json::Json) -> Option<SystemPoint> {
        Some(SystemPoint {
            response_s: v.get("response_s")?.as_f64()?,
            p50_s: v.get("p50_s")?.as_f64()?,
            p99_s: v.get("p99_s")?.as_f64()?,
            work_s: v.get("work_s")?.as_f64()?,
            net_s: v.get("net_s")?.as_f64()?,
            overhead_s: v.get("overhead_s")?.as_f64()?,
        })
    }
}

impl Sweep {
    /// Serialize for the bench-level cache.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("nodes", Json::from(p.nodes)),
                                ("docs", Json::from(p.docs)),
                                ("gaps", p.gaps.to_json()),
                                ("traditional", p.traditional.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("queries", Json::Arr(self.queries.iter().map(|q| Json::str(q.clone())).collect())),
        ])
    }

    /// Parse a cached sweep.
    pub fn from_json(v: &crate::util::json::Json) -> Option<Sweep> {
        let points = v
            .get("points")?
            .as_arr()?
            .iter()
            .map(|p| {
                Some(SweepPoint {
                    nodes: p.get("nodes")?.as_i64()? as usize,
                    docs: p.get("docs")?.as_i64()? as u64,
                    gaps: SystemPoint::from_json(p.get("gaps")?)?,
                    traditional: SystemPoint::from_json(p.get("traditional")?)?,
                })
            })
            .collect::<Option<Vec<_>>>()?;
        let queries = v
            .get("queries")?
            .as_arr()?
            .iter()
            .map(|q| q.as_str().map(|s| s.to_string()))
            .collect::<Option<Vec<_>>>()?;
        Some(Sweep { points, queries })
    }
}

/// Run a sweep, caching the result under target/sweep_cache keyed by the
/// workload signature — the three figure benches share one sweep instead
/// of re-running identical experiments. Delete target/sweep_cache to
/// force fresh measurements.
pub fn cached_node_sweep(cfg: &GapsConfig, node_counts: &[usize]) -> Result<Sweep> {
    // workers is in the key defensively: run_node_sweep currently forces
    // serial dispatch, but a cached sweep must never be reused across
    // execution modes if that ever changes.
    let key = format!(
        "docs{}_q{}_s{}_shards{}_seed{}_xla{}_w{}_counts{}",
        cfg.workload.num_docs,
        cfg.workload.num_queries,
        cfg.workload.seed,
        cfg.workload.sub_shards,
        cfg.grid.seed,
        cfg.search.use_xla,
        cfg.search.workers,
        node_counts.iter().map(|n| n.to_string()).collect::<Vec<_>>().join("-"),
    );
    let dir = std::path::Path::new("target/sweep_cache");
    let path = dir.join(format!("{key}.json"));
    if let Ok(text) = std::fs::read_to_string(&path) {
        if let Some(sweep) =
            crate::util::json::Json::parse(&text).ok().and_then(|v| Sweep::from_json(&v))
        {
            eprintln!("(using cached sweep {path:?}; delete to re-measure)");
            return Ok(sweep);
        }
    }
    let sweep = run_node_sweep(cfg, node_counts)?;
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(&path, sweep.to_json().to_string_pretty());
    Ok(sweep)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> GapsConfig {
        let mut cfg = GapsConfig::default();
        cfg.workload.num_docs = 400;
        cfg.workload.num_queries = 4;
        cfg.workload.sub_shards = 8;
        cfg.search.use_xla = false;
        cfg
    }

    #[test]
    fn sweep_produces_points_for_each_count() {
        let sweep = run_node_sweep(&tiny_cfg(), &[1, 2, 4]).unwrap();
        assert_eq!(sweep.points.len(), 3);
        assert_eq!(sweep.points[0].nodes, 1);
        for p in &sweep.points {
            assert!(p.gaps.response_s > 0.0);
            assert!(p.traditional.response_s > 0.0);
        }
    }

    #[test]
    fn speedup_and_efficiency_identities() {
        let sweep = run_node_sweep(&tiny_cfg(), &[1, 4]).unwrap();
        let serial = sweep.serial_response_s(System::Gaps);
        let p = &sweep.points[1];
        let s = p.speedup(serial, System::Gaps);
        let e = p.efficiency(serial, System::Gaps);
        assert!((e - s / 4.0).abs() < 1e-12);
        // 1-node point: speedup == 1 by construction.
        let p1 = &sweep.points[0];
        assert!((p1.speedup(serial, System::Gaps) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn query_mix_is_deterministic_and_multivariate() {
        let cfg = tiny_cfg();
        let dep = Deployment::build(&cfg, 2).unwrap();
        let a = sample_queries(&dep, 10, 7);
        let b = sample_queries(&dep, 10, 7);
        assert_eq!(a, b);
        assert!(a.iter().any(|q| q.contains("year:")), "{a:?}");
        assert!(a.iter().any(|q| !q.contains("year:")));
    }

    #[test]
    fn sweep_json_roundtrip() {
        let sweep = run_node_sweep(&tiny_cfg(), &[1, 2]).unwrap();
        let parsed = Sweep::from_json(&sweep.to_json()).unwrap();
        assert_eq!(parsed.points.len(), 2);
        assert_eq!(parsed.queries, sweep.queries);
        assert!((parsed.points[1].gaps.response_s - sweep.points[1].gaps.response_s).abs() < 1e-12);
    }

    #[test]
    fn gaps_beats_traditional_at_scale() {
        // The paper's headline: GAPS responds faster than traditional for
        // multi-node grids. Even this tiny corpus shows it because the
        // baseline pays cold starts + serial WAN dispatch.
        let sweep = run_node_sweep(&tiny_cfg(), &[4]).unwrap();
        let p = &sweep.points[0];
        assert!(
            p.gaps.response_s < p.traditional.response_s,
            "gaps {} !< traditional {}",
            p.gaps.response_s,
            p.traditional.response_s
        );
    }
}
