//! The PJRT executor: compile-once, execute-many ranking.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::artifacts::Manifest;
use crate::index::{GlobalStats, PackedBlock, Packer, Shard};
use crate::text::NUM_FIELDS;

/// Ranked output for one query row: (block-local index, score), sorted by
/// score descending; padding rows already filtered out.
pub type RankOutput = Vec<(u32, f32)>;

/// Compile-once executor over the artifact set.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// artifact name -> compiled executable.
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Reusable dense packer (§Perf P2: sparse-clear instead of an 8 MB
    /// zero per ranking call).
    packer: Packer,
    /// Executions performed (metrics).
    executions: u64,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("artifacts", &self.manifest.artifacts.len())
            .field("compiled", &self.compiled.len())
            .field("executions", &self.executions)
            .finish()
    }
}

impl Executor {
    /// Create a CPU PJRT client and eagerly compile every artifact in
    /// `dir` (startup cost, off the request path).
    pub fn new(dir: &Path) -> Result<Executor> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let mut compiled = HashMap::new();
        for spec in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                spec.file.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("loading {}: {e}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", spec.name))?;
            compiled.insert(spec.name.clone(), exe);
        }
        Ok(Executor { client, manifest, compiled, packer: Packer::new(), executions: 0 })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn executions(&self) -> u64 {
        self.executions
    }

    /// Platform name of the PJRT backend (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Pack candidates with the reused internal packer and rank them —
    /// the Search Service's hot path. Picks the smallest artifact fitting
    /// the candidate count, packs exactly to its D (sparse-clear reuse),
    /// and executes.
    pub fn rank_candidates(
        &mut self,
        shard: &Shard,
        stats: &GlobalStats,
        candidates: &[u32],
        qw: &[f32],
        q_count: usize,
        field_w: &[f32; NUM_FIELDS],
        b: f32,
    ) -> Result<Vec<RankOutput>> {
        let spec_d = self
            .manifest
            .select(q_count, candidates.len(), shard.features)
            .map(|a| a.d)
            .with_context(|| {
                format!("no artifact fits q={q_count} cand={} f={}", candidates.len(), shard.features)
            })?;
        // Split borrows: move the packer out while ranking.
        let mut packer = std::mem::take(&mut self.packer);
        let result = {
            let block = packer.pack(shard, stats, candidates, spec_d, b);
            self.rank(block, qw, q_count, field_w)
        };
        self.packer = packer;
        result
    }

    /// Rank a packed candidate block for `q_count` queries.
    ///
    /// `qw` is row-major `[q_capacity, F]` with `q_capacity >= q_count`
    /// (unused rows zero). `field_w` are the ABI field weights. Selects
    /// the smallest artifact variant fitting (q_count, block.d, block.f);
    /// the block must have been packed to that variant's D — callers use
    /// [`Manifest::select`]/[`Manifest::max_block`] to size blocks.
    pub fn rank(
        &mut self,
        block: &PackedBlock,
        qw: &[f32],
        q_count: usize,
        field_w: &[f32; NUM_FIELDS],
    ) -> Result<Vec<RankOutput>> {
        let spec = self
            .manifest
            .select(q_count, block.d, block.f)
            .with_context(|| {
                format!("no artifact fits q={q_count} d={} f={}", block.d, block.f)
            })?
            .clone();
        if spec.d != block.d {
            anyhow::bail!(
                "block packed to d={} but artifact {} expects d={}",
                block.d,
                spec.name,
                spec.d
            );
        }
        let exe = self.compiled.get(&spec.name).context("artifact not compiled")?;

        // Build input device buffers in ABI order: doc_tf, len_norm,
        // field_w, qw. NOTE: we deliberately use `buffer_from_host_buffer`
        // + `execute_b` instead of `execute::<Literal>`: the crate's
        // literal-based execute `release()`s the device buffers it creates
        // for the inputs and never frees them (xla_rs.cc `execute`), which
        // leaks ~8 MB per ranking call. PjRtBuffer has a proper Drop.
        let device = None;
        let buf_doc_tf = self
            .client
            .buffer_from_host_buffer(&block.doc_tf, &[spec.nf, spec.d, spec.f], device)
            .map_err(|e| anyhow!("doc_tf transfer: {e}"))?;
        let buf_len_norm = self
            .client
            .buffer_from_host_buffer(&block.len_norm, &[spec.nf, spec.d], device)
            .map_err(|e| anyhow!("len_norm transfer: {e}"))?;
        let buf_field_w = self
            .client
            .buffer_from_host_buffer(&field_w[..], &[spec.nf], device)
            .map_err(|e| anyhow!("field_w transfer: {e}"))?;
        // qw may be sized for fewer rows than the artifact Q: zero-pad.
        let mut qw_padded;
        let qw_slice: &[f32] = if qw.len() == spec.q * spec.f {
            qw
        } else {
            anyhow::ensure!(
                qw.len() >= q_count * spec.f,
                "qw len {} < q_count {} x f {}",
                qw.len(),
                q_count,
                spec.f
            );
            qw_padded = vec![0.0f32; spec.q * spec.f];
            qw_padded[..q_count * spec.f].copy_from_slice(&qw[..q_count * spec.f]);
            &qw_padded
        };
        let buf_qw = self
            .client
            .buffer_from_host_buffer(qw_slice, &[spec.q, spec.f], device)
            .map_err(|e| anyhow!("qw transfer: {e}"))?;

        let result = exe
            .execute_b::<xla::PjRtBuffer>(&[buf_doc_tf, buf_len_norm, buf_field_w, buf_qw])
            .map_err(|e| anyhow!("executing {}: {e}", spec.name))?;
        self.executions += 1;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e}"))?;
        let (vals, idx) = tuple.to_tuple2().map_err(|e| anyhow!("untupling: {e}"))?;
        let vals: Vec<f32> = vals.to_vec().map_err(|e| anyhow!("scores: {e}"))?;
        let idx: Vec<i32> = idx.to_vec().map_err(|e| anyhow!("indices: {e}"))?;
        anyhow::ensure!(vals.len() == spec.q * spec.k, "bad scores shape");
        anyhow::ensure!(idx.len() == spec.q * spec.k, "bad indices shape");

        // Unpack per query row; drop padding (idx >= n_real) and zero-score
        // tail entries that are padding artifacts.
        let mut out = Vec::with_capacity(q_count);
        for q in 0..q_count {
            let mut row = Vec::with_capacity(spec.k);
            for j in 0..spec.k {
                let i = idx[q * spec.k + j];
                let v = vals[q * spec.k + j];
                if (i as usize) < block.n_real {
                    row.push((i as u32, v));
                }
            }
            out.push(row);
        }
        Ok(out)
    }
}

// NOTE: integration coverage for the executor lives in
// rust/tests/integration_runtime.rs (it needs built artifacts, a PJRT
// client, and real blocks); there are no artifact-free unit tests here.
