//! Stub executor: compiled in when the `xla` feature is off.
//!
//! The offline crate set does not always carry the `xla` PJRT bindings,
//! so the real executor (executor.rs) is feature-gated. This stub keeps
//! the whole coordinator/service surface compiling unchanged: it exposes
//! the same API and fails cleanly at construction, which `GapsSystem::
//! from_deployment` surfaces as a deploy-time error when `use_xla = true`.
//! Every artifact-free path (rust scorer, benches, tests) never touches
//! it.

use std::path::Path;

use anyhow::{bail, Result};

use super::artifacts::Manifest;
use crate::index::{GlobalStats, PackedBlock, Shard};
use crate::text::NUM_FIELDS;

/// Ranked output for one query row: (block-local index, score).
pub type RankOutput = Vec<(u32, f32)>;

/// Never constructed without the `xla` feature; the field exists so the
/// accessors below typecheck against the real executor's signatures.
pub struct Executor {
    manifest: Manifest,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor").field("stub", &true).finish()
    }
}

impl Executor {
    pub fn new(_dir: &Path) -> Result<Executor> {
        bail!(
            "built without the `xla` feature: the PJRT runtime is unavailable \
             (set search.use_xla = false / pass --no-xla, or rebuild with \
             --features xla in an environment that vendors the xla crate)"
        )
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn executions(&self) -> u64 {
        0
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    #[allow(clippy::too_many_arguments)]
    pub fn rank_candidates(
        &mut self,
        _shard: &Shard,
        _stats: &GlobalStats,
        _candidates: &[u32],
        _qw: &[f32],
        _q_count: usize,
        _field_w: &[f32; NUM_FIELDS],
        _b: f32,
    ) -> Result<Vec<RankOutput>> {
        bail!("xla feature disabled")
    }

    pub fn rank(
        &mut self,
        _block: &PackedBlock,
        _qw: &[f32],
        _q_count: usize,
        _field_w: &[f32; NUM_FIELDS],
    ) -> Result<Vec<RankOutput>> {
        bail!("xla feature disabled")
    }
}
