//! PJRT runtime: loads the AOT HLO artifacts produced by the python
//! compile path and executes them from the rust request path.
//!
//! Wiring (see /opt/xla-example/load_hlo): HLO *text* ->
//! `HloModuleProto::from_text_file` -> `XlaComputation` -> `PjRtClient::
//! cpu().compile` -> `execute`. Artifacts are compiled once at startup
//! and cached; Python never runs at request time.
//!
//! Threading: the `xla` crate's PJRT handles are raw pointers without
//! Send/Sync, so the executor is owned by the coordinator thread and all
//! artifact executions are serialized through it (the coordinator's
//! parallel shard fan-out applies to the rust-scorer path only);
//! node-level parallelism is accounted through the simulated timelines
//! (ARCHITECTURE.md §Substitutions).
//!
//! Build gating: the real executor needs the `xla` crate, which the
//! offline crate set may lack — it compiles behind the `xla` feature,
//! with `executor_stub.rs` standing in otherwise (same API, errors at
//! construction).

mod artifacts;
#[cfg(feature = "xla")]
mod executor;
#[cfg(not(feature = "xla"))]
#[path = "executor_stub.rs"]
mod executor;

pub use artifacts::{ArtifactSpec, Manifest};
#[allow(unused_imports)]
pub use executor::{RankOutput, Executor};
