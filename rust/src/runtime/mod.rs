//! PJRT runtime: loads the AOT HLO artifacts produced by the python
//! compile path and executes them from the rust request path.
//!
//! Wiring (see /opt/xla-example/load_hlo and DESIGN.md): HLO *text* ->
//! `HloModuleProto::from_text_file` -> `XlaComputation` -> `PjRtClient::
//! cpu().compile` -> `execute`. Artifacts are compiled once at startup
//! and cached; Python never runs at request time.
//!
//! Threading: the `xla` crate's PJRT handles are raw pointers without
//! Send/Sync, so the executor is owned by the coordinator thread and all
//! artifact executions are serialized through it. On this 1-core testbed
//! that costs nothing; node-level parallelism is accounted through the
//! simulated timelines (DESIGN.md §Substitutions).

mod artifacts;
mod executor;

pub use artifacts::{ArtifactSpec, Manifest};
#[allow(unused_imports)]
pub use executor::{RankOutput, Executor};
