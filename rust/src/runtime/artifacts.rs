//! Artifact manifest: what the python AOT path shipped.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One AOT-compiled ranker variant (shape signature + file).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    /// Query batch capacity.
    pub q: usize,
    /// Candidate block capacity.
    pub d: usize,
    /// Feature dimension per field.
    pub f: usize,
    /// Top-k per block.
    pub k: usize,
    /// Number of fields.
    pub nf: usize,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    /// BM25 k1 baked into the artifacts at lowering time.
    pub k1: f64,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let k1 = v
            .req("abi")
            .and_then(|abi| abi.req("k1"))
            .ok()
            .and_then(|x| x.as_f64())
            .context("manifest abi.k1 missing")?;
        let arts = v
            .req("artifacts")
            .ok()
            .and_then(|a| a.as_arr().map(|s| s.to_vec()))
            .context("manifest artifacts missing")?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in &arts {
            let get_usize = |key: &str| -> Result<usize> {
                a.get(key)
                    .and_then(|x| x.as_i64())
                    .filter(|x| *x > 0)
                    .map(|x| x as usize)
                    .with_context(|| format!("artifact field '{key}'"))
            };
            let name = a
                .get("name")
                .and_then(|x| x.as_str())
                .context("artifact name")?
                .to_string();
            let file = a
                .get("file")
                .and_then(|x| x.as_str())
                .context("artifact file")?;
            artifacts.push(ArtifactSpec {
                name,
                file: dir.join(file),
                q: get_usize("q")?,
                d: get_usize("d")?,
                f: get_usize("f")?,
                k: get_usize("k")?,
                nf: get_usize("nf")?,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest { artifacts, k1 })
    }

    /// Pick the smallest variant that fits `q` queries, `cand` candidates
    /// and feature dim `f` — smallest D minimizes padding waste, then
    /// smallest Q.
    pub fn select(&self, q: usize, cand: usize, f: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.q >= q && a.d >= cand && a.f == f)
            .min_by_key(|a| (a.d, a.q))
    }

    /// The largest candidate capacity available for feature dim `f`
    /// (callers chunk candidate lists to this).
    pub fn max_block(&self, q: usize, f: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.q >= q && a.f == f)
            .max_by_key(|a| a.d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn sample() -> &'static str {
        r#"{
          "abi": {"k1": 1.2, "return_tuple": true},
          "artifacts": [
            {"name": "a", "file": "a.hlo.txt", "q": 1, "d": 256, "f": 512, "k": 32, "nf": 4},
            {"name": "b", "file": "b.hlo.txt", "q": 1, "d": 1024, "f": 512, "k": 32, "nf": 4},
            {"name": "c", "file": "c.hlo.txt", "q": 8, "d": 256, "f": 512, "k": 32, "nf": 4}
          ]
        }"#
    }

    #[test]
    fn load_and_select() {
        let dir = std::env::temp_dir().join("gaps_manifest_test");
        write_manifest(&dir, sample());
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.k1, 1.2);
        // Fits in the small block.
        assert_eq!(m.select(1, 100, 512).unwrap().name, "a");
        // Needs the big block.
        assert_eq!(m.select(1, 700, 512).unwrap().name, "b");
        // Batched queries force the q8 variant.
        assert_eq!(m.select(4, 200, 512).unwrap().name, "c");
        // Nothing fits.
        assert!(m.select(1, 5000, 512).is_none());
        assert!(m.select(1, 10, 999).is_none());
        // Largest block for chunking.
        assert_eq!(m.max_block(1, 512).unwrap().d, 1024);
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn malformed_manifest_rejected() {
        let dir = std::env::temp_dir().join("gaps_manifest_bad");
        write_manifest(&dir, r#"{"abi": {"k1": 1.2}, "artifacts": []}"#);
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, r#"{"artifacts": [{}]}"#);
        assert!(Manifest::load(&dir).is_err());
    }
}
