//! Deterministic fault injection for the executor path.
//!
//! The paper's grid treats node churn as the normal case ("organizations
//! resources that join or leaves the system at any time"); this module is
//! the harness that makes that case *testable*: a seeded [`ChaosPlan`]
//! assigns each node at most one [`FaultKind`], and a [`FaultInjector`]
//! turns the plan into per-job [`FaultDecision`]s at the `run_job`
//! fail-point inside `coordinator::system`. There is **no randomness at
//! runtime** — every schedule is a pure function of its `u64` seed (via
//! [`crate::util::rng::Rng`]) plus the deterministic order of injector
//! consultations, so any chaos run (and any failure it uncovers) replays
//! exactly from the recorded seed.
//!
//! The injector also answers health *probes* (the `ResourceManager`'s
//! probation checks): crashed nodes stay unhealthy, slow nodes probe
//! healthy, and flaky nodes recover once their failure budget is spent —
//! which is how `flaky-N-then-recover` schedules exercise the
//! down/probation/rejoin lifecycle end to end.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::grid::NodeId;
use crate::util::rng::Rng;

/// A node's scripted misbehavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Every job crashes before touching any source (permanent).
    CrashBeforeExecute,
    /// Every job crashes after processing half its sources (permanent).
    /// Partial work is discarded by the coordinator — re-searching a
    /// source is idempotent.
    CrashMidBatch,
    /// Jobs complete, but only after an injected delay (never crashes).
    SlowNode { delay_ms: u64 },
    /// The first `failures` consultations (jobs *or* health probes)
    /// fail; afterwards the node behaves normally — the shape that
    /// exercises probation recovery.
    FlakyThenRecover { failures: u32 },
}

impl FaultKind {
    /// Whether this fault can make a job crash (as opposed to merely
    /// slowing it down). Used by the chaos property test to check that a
    /// degraded response's missing-source list is *truthful*: every
    /// replica of a missing source must be crash-capable.
    pub fn can_crash(self) -> bool {
        !matches!(self, FaultKind::SlowNode { .. })
    }
}

/// What the injector tells `run_job` to do for one job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultDecision {
    /// Execute normally.
    Proceed,
    /// Sleep this long, then execute normally (the sleep is wall-clock
    /// only — measured work and scores are untouched).
    Delay(Duration),
    /// Fail before processing any source.
    CrashBefore,
    /// Process half the job's sources, then fail.
    CrashMid,
}

/// A seeded per-node fault schedule. Immutable once built; share one
/// plan between the system under test and the assertions checking it.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosPlan {
    /// The seed this plan was derived from (0 for hand-built plans).
    pub seed: u64,
    faults: BTreeMap<NodeId, FaultKind>,
}

impl ChaosPlan {
    /// An empty plan (no faults). Add nodes with [`ChaosPlan::with_fault`].
    pub fn new() -> ChaosPlan {
        ChaosPlan::default()
    }

    /// Derive a schedule for `nodes` from a seed: each node independently
    /// stays healthy with probability 1/2, otherwise draws a uniform
    /// fault kind (delays 1..=5 ms, flaky budgets 1..=3 failures).
    pub fn from_seed(seed: u64, nodes: &[NodeId]) -> ChaosPlan {
        let mut rng = Rng::new(seed);
        let mut faults = BTreeMap::new();
        for &node in nodes {
            // One fork per node: a node's fault depends only on (seed,
            // node id), not on how many nodes precede it in the slice.
            let mut r = rng.fork(node.0 as u64 + 1);
            if r.chance(0.5) {
                continue;
            }
            let kind = match r.below(4) {
                0 => FaultKind::CrashBeforeExecute,
                1 => FaultKind::CrashMidBatch,
                2 => FaultKind::SlowNode { delay_ms: 1 + r.below(5) },
                _ => FaultKind::FlakyThenRecover { failures: 1 + r.below(3) as u32 },
            };
            faults.insert(node, kind);
        }
        // Consume the parent stream so two plans built back to back from
        // the same Rng-seeded driver do not alias.
        let _ = rng.next_u64();
        ChaosPlan { seed, faults }
    }

    /// Script one node's fault (builder form, for directed tests).
    pub fn with_fault(mut self, node: NodeId, kind: FaultKind) -> ChaosPlan {
        self.faults.insert(node, kind);
        self
    }

    /// The scripted fault for a node, if any.
    pub fn fault(&self, node: NodeId) -> Option<FaultKind> {
        self.faults.get(&node).copied()
    }

    /// Nodes with a scripted fault, in id order.
    pub fn faulty_nodes(&self) -> Vec<NodeId> {
        self.faults.keys().copied().collect()
    }

    /// Whether a node's scripted fault can crash jobs (healthy and
    /// slow-only nodes are not crash-capable).
    pub fn can_crash(&self, node: NodeId) -> bool {
        self.fault(node).map(FaultKind::can_crash).unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }
}

/// Runtime state over a [`ChaosPlan`]: tracks per-node strike counts so
/// `flaky-N-then-recover` schedules are stateful but still deterministic
/// (the count of consultations per node is fixed by the schedule, not by
/// thread timing). `Sync` so the gridpool fan-out can consult it from
/// worker threads.
#[derive(Debug)]
pub struct FaultInjector {
    plan: ChaosPlan,
    /// Consultations consumed per flaky node.
    strikes: Mutex<BTreeMap<NodeId, u32>>,
}

impl FaultInjector {
    pub fn new(plan: ChaosPlan) -> FaultInjector {
        FaultInjector { plan, strikes: Mutex::new(BTreeMap::new()) }
    }

    /// The schedule this injector executes.
    pub fn plan(&self) -> &ChaosPlan {
        &self.plan
    }

    /// Decide one job's fate on `node` (the `run_job` fail-point).
    pub fn decide(&self, node: NodeId) -> FaultDecision {
        match self.plan.fault(node) {
            None => FaultDecision::Proceed,
            Some(FaultKind::CrashBeforeExecute) => FaultDecision::CrashBefore,
            Some(FaultKind::CrashMidBatch) => FaultDecision::CrashMid,
            Some(FaultKind::SlowNode { delay_ms }) => {
                FaultDecision::Delay(Duration::from_millis(delay_ms))
            }
            Some(FaultKind::FlakyThenRecover { failures }) => {
                if self.consume_strike(node, failures) {
                    FaultDecision::CrashBefore
                } else {
                    FaultDecision::Proceed
                }
            }
        }
    }

    /// Answer a health probe (the `ResourceManager` probation check).
    /// Probes *consume* flaky strikes like jobs do, so a flaky node
    /// recovers after its budget whichever way it is exercised.
    pub fn probe_healthy(&self, node: NodeId) -> bool {
        match self.plan.fault(node) {
            None | Some(FaultKind::SlowNode { .. }) => true,
            Some(FaultKind::CrashBeforeExecute) | Some(FaultKind::CrashMidBatch) => false,
            Some(FaultKind::FlakyThenRecover { failures }) => {
                !self.consume_strike(node, failures)
            }
        }
    }

    /// True while the node still has failure budget (and burns one unit).
    fn consume_strike(&self, node: NodeId, failures: u32) -> bool {
        let mut strikes = self.strikes.lock().unwrap();
        let used = strikes.entry(node).or_insert(0);
        if *used < failures {
            *used += 1;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: u32) -> Vec<NodeId> {
        (0..n).map(NodeId).collect()
    }

    #[test]
    fn same_seed_same_plan() {
        let a = ChaosPlan::from_seed(0xFEED, &nodes(12));
        let b = ChaosPlan::from_seed(0xFEED, &nodes(12));
        assert_eq!(a, b, "schedules must replay from the seed");
    }

    #[test]
    fn different_seeds_diverge() {
        let plans: Vec<ChaosPlan> =
            (0..16).map(|s| ChaosPlan::from_seed(s, &nodes(12))).collect();
        let distinct: std::collections::BTreeSet<String> =
            plans.iter().map(|p| format!("{:?}", p.faulty_nodes())).collect();
        assert!(distinct.len() > 1, "16 seeds produced identical schedules");
    }

    #[test]
    fn node_fault_is_independent_of_slice_order() {
        // A node's fault depends on (seed, node id) only.
        let all = ChaosPlan::from_seed(7, &nodes(12));
        let tail = ChaosPlan::from_seed(7, &[NodeId(10), NodeId(11)]);
        assert_eq!(all.fault(NodeId(10)), tail.fault(NodeId(10)));
        assert_eq!(all.fault(NodeId(11)), tail.fault(NodeId(11)));
    }

    #[test]
    fn seeds_cover_every_fault_kind() {
        let mut seen_crash = false;
        let mut seen_mid = false;
        let mut seen_slow = false;
        let mut seen_flaky = false;
        for seed in 0..64 {
            for n in nodes(12) {
                match ChaosPlan::from_seed(seed, &nodes(12)).fault(n) {
                    Some(FaultKind::CrashBeforeExecute) => seen_crash = true,
                    Some(FaultKind::CrashMidBatch) => seen_mid = true,
                    Some(FaultKind::SlowNode { delay_ms }) => {
                        assert!((1..=5).contains(&delay_ms));
                        seen_slow = true;
                    }
                    Some(FaultKind::FlakyThenRecover { failures }) => {
                        assert!((1..=3).contains(&failures));
                        seen_flaky = true;
                    }
                    None => {}
                }
            }
        }
        assert!(seen_crash && seen_mid && seen_slow && seen_flaky);
    }

    #[test]
    fn crash_capability_excludes_slow_nodes() {
        let plan = ChaosPlan::new()
            .with_fault(NodeId(0), FaultKind::CrashBeforeExecute)
            .with_fault(NodeId(1), FaultKind::SlowNode { delay_ms: 2 })
            .with_fault(NodeId(2), FaultKind::FlakyThenRecover { failures: 1 });
        assert!(plan.can_crash(NodeId(0)));
        assert!(!plan.can_crash(NodeId(1)));
        assert!(plan.can_crash(NodeId(2)));
        assert!(!plan.can_crash(NodeId(3)), "healthy nodes cannot crash");
    }

    #[test]
    fn permanent_crashes_never_recover() {
        let inj = FaultInjector::new(
            ChaosPlan::new().with_fault(NodeId(0), FaultKind::CrashBeforeExecute),
        );
        for _ in 0..5 {
            assert_eq!(inj.decide(NodeId(0)), FaultDecision::CrashBefore);
            assert!(!inj.probe_healthy(NodeId(0)));
        }
        assert_eq!(inj.decide(NodeId(9)), FaultDecision::Proceed, "unscripted node");
        assert!(inj.probe_healthy(NodeId(9)));
    }

    #[test]
    fn flaky_recovers_after_its_budget() {
        let inj = FaultInjector::new(
            ChaosPlan::new().with_fault(NodeId(3), FaultKind::FlakyThenRecover { failures: 2 }),
        );
        assert_eq!(inj.decide(NodeId(3)), FaultDecision::CrashBefore);
        assert_eq!(inj.decide(NodeId(3)), FaultDecision::CrashBefore);
        assert_eq!(inj.decide(NodeId(3)), FaultDecision::Proceed, "budget spent");
        assert!(inj.probe_healthy(NodeId(3)));
    }

    #[test]
    fn probes_consume_flaky_strikes_too() {
        let inj = FaultInjector::new(
            ChaosPlan::new().with_fault(NodeId(3), FaultKind::FlakyThenRecover { failures: 1 }),
        );
        assert!(!inj.probe_healthy(NodeId(3)), "first probe burns the strike");
        assert!(inj.probe_healthy(NodeId(3)));
        assert_eq!(inj.decide(NodeId(3)), FaultDecision::Proceed);
    }

    #[test]
    fn slow_nodes_delay_but_stay_healthy() {
        let inj = FaultInjector::new(
            ChaosPlan::new().with_fault(NodeId(1), FaultKind::SlowNode { delay_ms: 4 }),
        );
        assert_eq!(
            inj.decide(NodeId(1)),
            FaultDecision::Delay(Duration::from_millis(4))
        );
        assert!(inj.probe_healthy(NodeId(1)));
    }
}
