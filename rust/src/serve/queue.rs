//! Admission queue: coalesces concurrently arriving, independent
//! [`SearchRequest`]s into one `search_batch` call.
//!
//! This is the paper's multi-user workload expressed on the typed search
//! surface: many users submit single queries, the grid executes *rounds*.
//! PR 2's batching made one round cheap for Q queries (one plan, one JDF
//! per node, one fan-out); the admission queue is the front that turns
//! independent concurrent submissions into such rounds.
//!
//! Mechanics: submitters enqueue `(request, reply slot)` pairs under one
//! mutex — arrival order is the lock acquisition order and is the
//! **deterministic drain order**. A single executor (the thread that owns
//! the `GapsSystem`) pops batches with [`AdmissionQueue::next_batch`]:
//! it waits for the first pending request, then *lingers* up to
//! [`QueueConfig::max_linger`] past that request's arrival for
//! co-arrivals (or until [`QueueConfig::max_batch`] are waiting), then
//! drains FIFO. Coalescing changes *when* work happens, never *what* is
//! returned: batch execution is bit-identical to sequential execution
//! (`tests/prop_batch_parity.rs`), so a coalesced user observes exactly
//! the hits a dedicated system would have produced
//! (`tests/prop_serve_parity.rs`).
//!
//! **Back-pressure:** the queue has a high-water mark
//! ([`QueueConfig::max_depth`]). Submissions beyond it are shed
//! immediately with a typed [`SearchError::Overloaded`] carrying a
//! retry hint — bounded queues fail fast instead of building unbounded
//! latency. Requests whose [`SearchRequest::deadline_ms`] already
//! elapsed *while queued* are settled with `DeadlineExceeded` at drain
//! time instead of wasting executor work.
//!
//! [`QueueStats`] counts admissions/batches/coalesced/shed/expired
//! requests; the HTTP front-end exposes them on `GET /healthz` so
//! coalescing and load shedding are observable from outside.
//!
//! **Ingestion lane:** the queue carries a second, search-independent
//! lane of [`Publication`] batches (`POST /ingest`). The executor drains
//! rounds with [`AdmissionQueue::next_round`]: a pending ingest batch
//! runs *first* and without linger (writes never wait on a search
//! coalescing window), then search rounds drain exactly as
//! [`AdmissionQueue::next_batch`] would have — the search lane's
//! semantics (and its fourteen unit tests) are untouched. After every
//! ingest round the executor publishes the system's [`IndexHealth`]
//! into the queue's health cell, which `GET /healthz` reports as the
//! `index` object — epoch bumps from seals and merges are visible to
//! clients without touching the executor.
//!
//! **Single-flight coalescing:** a submission identical (by
//! [`SearchRequest`] equality) to a request already waiting attaches to
//! it instead of occupying its own queue slot — the round executes the
//! query once and [`AdmittedBatch::complete`] fans the one result out
//! to every attached submitter. Deadlined requests are exempt (expiry
//! is anchored at each submission's own arrival), and attachments are
//! absorbed even at the high-water mark since they do not grow the
//! queue. Counted in [`QueueStats::singleflight`].
//!
//! **Caching:** the executor loop ([`run`]) owns a
//! [`super::cache::ResultCache`] and compiles requests through
//! [`GapsSystem::compile_request`]'s plan cache: repeats of a hot query
//! skip parse + plan, and result-cache hits skip the grid round
//! entirely. Entries are keyed on the normalized-AST fingerprint plus
//! the index epoch, and the whole cache is dropped when an ingest round
//! moves the epoch — a seal or merge can never leave stale hits behind.
//! The cache counters are published into [`QueueStats`] after every
//! round, so `GET /healthz` exposes them.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{
    counters_to_json, FailoverStats, GapsSystem, IndexHealth, IngestReport, SearchResponse,
};
use crate::corpus::Publication;
use crate::obs::{Counter, Gauge, Registry, SlowEntry, TraceSpan, LATENCY_BOUNDS_S};
use crate::search::{CompiledRequest, SearchError, SearchRequest};
use crate::serve::cache::{CacheCounters, ResultCache};
use crate::serve::ServeObs;
use crate::util::clock::WallClock;
use crate::util::json::Json;

/// Coalescing knobs (the `gaps serve` CLI exposes both).
#[derive(Debug, Clone, Copy)]
pub struct QueueConfig {
    /// Most requests coalesced into one `search_batch` call (>= 1).
    pub max_batch: usize,
    /// How long a drain waits past the oldest pending request's arrival
    /// for co-arriving requests. Zero means "drain whatever is queued
    /// the moment the executor looks".
    pub max_linger: Duration,
    /// High-water mark: submissions beyond this many pending requests
    /// are shed with [`SearchError::Overloaded`] instead of queued.
    pub max_depth: usize,
}

impl Default for QueueConfig {
    fn default() -> QueueConfig {
        QueueConfig { max_batch: 16, max_linger: Duration::from_millis(2), max_depth: 1024 }
    }
}

/// Deterministic admission counters (exposed via `GET /healthz`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// Requests accepted into the queue (including single-flight
    /// attachments).
    pub submitted: u64,
    /// Requests answered by executor rounds, including single-flight
    /// attachments fanned out at completion (== `submitted` once
    /// drained).
    pub executed: u64,
    /// `search_batch` rounds the executor ran.
    pub batches: u64,
    /// Requests that shared their round with at least one other request
    /// — the observable evidence of coalescing. Counts distinct queue
    /// slots only; single-flight attachments are counted in
    /// [`QueueStats::singleflight`] instead.
    pub coalesced: u64,
    /// Largest round drained so far (distinct queue slots; attachments
    /// do not occupy slots).
    pub largest_batch: u64,
    /// Submissions that attached to an identical already-pending
    /// request (single-flight): their query executed once and the
    /// result was fanned out.
    pub singleflight: u64,
    /// Submissions rejected at the high-water mark (load shedding).
    pub shed: u64,
    /// Requests whose deadline elapsed while queued (settled at drain
    /// time without reaching the executor).
    pub expired: u64,
    /// Ingest batches accepted into the ingestion lane.
    pub ingest_batches: u64,
    /// Publications accepted across all ingest batches.
    pub ingest_docs: u64,
    /// Compiled-plan cache hits (executor-published; a hit skips
    /// lex + parse + plan for the round's request).
    pub plan_hits: u64,
    /// Compiled-plan cache misses (executor-published).
    pub plan_misses: u64,
    /// Result-cache hits (executor-published; a hit skips the grid
    /// round entirely).
    pub result_hits: u64,
    /// Result-cache misses (executor-published).
    pub result_misses: u64,
    /// Result-cache entries dropped by capacity eviction.
    pub result_evicted: u64,
    /// Result-cache entries dropped wholesale by index-epoch bumps.
    pub result_invalidated: u64,
}

impl QueueStats {
    /// JSON form (the `/healthz` `queue` object).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("submitted", Json::from(self.submitted)),
            ("executed", Json::from(self.executed)),
            ("batches", Json::from(self.batches)),
            ("coalesced", Json::from(self.coalesced)),
            ("largest_batch", Json::from(self.largest_batch)),
            ("shed", Json::from(self.shed)),
            ("expired", Json::from(self.expired)),
            ("ingest_batches", Json::from(self.ingest_batches)),
            ("ingest_docs", Json::from(self.ingest_docs)),
            ("singleflight", Json::from(self.singleflight)),
            ("plan_hits", Json::from(self.plan_hits)),
            ("plan_misses", Json::from(self.plan_misses)),
            ("result_hits", Json::from(self.result_hits)),
            ("result_misses", Json::from(self.result_misses)),
            ("result_evicted", Json::from(self.result_evicted)),
            ("result_invalidated", Json::from(self.result_invalidated)),
        ])
    }

    /// Fold another shard's counters into this snapshot: every counter
    /// sums except `largest_batch`, which is a high-water mark and takes
    /// the max. Used by the shard router to present one aggregate
    /// `queue` object on `/healthz` next to the per-shard breakdown.
    pub fn absorb(&mut self, other: &QueueStats) {
        self.submitted += other.submitted;
        self.executed += other.executed;
        self.batches += other.batches;
        self.coalesced += other.coalesced;
        self.largest_batch = self.largest_batch.max(other.largest_batch);
        self.singleflight += other.singleflight;
        self.shed += other.shed;
        self.expired += other.expired;
        self.ingest_batches += other.ingest_batches;
        self.ingest_docs += other.ingest_docs;
        self.plan_hits += other.plan_hits;
        self.plan_misses += other.plan_misses;
        self.result_hits += other.result_hits;
        self.result_misses += other.result_misses;
        self.result_evicted += other.result_evicted;
        self.result_invalidated += other.result_invalidated;
    }
}

/// One enqueued request plus its way back to the submitter.
struct Pending {
    request: SearchRequest,
    arrived: Instant,
    reply: mpsc::Sender<Result<SearchResponse, SearchError>>,
    /// Reply slots of identical submissions that attached to this one
    /// (single-flight): the round executes `request` once, completion
    /// fans the result out to every slot.
    extra_replies: Vec<mpsc::Sender<Result<SearchResponse, SearchError>>>,
}

/// One enqueued ingest batch plus its way back to the submitter.
struct IngestPending {
    docs: Vec<Publication>,
    reply: mpsc::Sender<Result<IngestReport, SearchError>>,
}

struct Inner {
    pending: VecDeque<Pending>,
    /// The ingestion lane: drained ahead of search rounds, no linger.
    ingest_pending: VecDeque<IngestPending>,
    /// `false` after [`AdmissionQueue::shutdown`]: new submissions are
    /// rejected; already-pending requests still drain.
    open: bool,
    /// Last [`IndexHealth`] the executor published (after deployment and
    /// after every ingest round). `None` until the executor first runs.
    index_health: Option<IndexHealth>,
}

/// The queue's admission counters as [`Registry`] cells. Mutations
/// happen under the queue mutex (so relative ordering is exactly what
/// it was when these lived in a plain struct), and [`QueueStats`] is
/// reassembled from the cells on read — `/healthz` and `/metrics` are
/// two renderings of the same source of truth.
struct QueueMetrics {
    submitted: Counter,
    executed: Counter,
    batches: Counter,
    coalesced: Counter,
    largest_batch: Gauge,
    singleflight: Counter,
    shed: Counter,
    expired: Counter,
    ingest_batches: Counter,
    ingest_docs: Counter,
    plan_hits: Counter,
    plan_misses: Counter,
    result_hits: Counter,
    result_misses: Counter,
    result_evicted: Counter,
    result_invalidated: Counter,
    /// Instantaneous queue depth (distinct pending slots).
    depth: Gauge,
}

impl QueueMetrics {
    fn new(registry: &Registry, shard: Option<usize>) -> QueueMetrics {
        let shard_value = shard.map(|s| s.to_string());
        let labels: Vec<(&str, &str)> = match &shard_value {
            Some(v) => vec![("shard", v.as_str())],
            None => Vec::new(),
        };
        let counter = |name: &str, help: &str| registry.counter_with(name, help, &labels);
        let gauge = |name: &str, help: &str| registry.gauge_with(name, help, &labels);
        QueueMetrics {
            submitted: counter("gaps_queue_submitted_total", "Requests accepted into the queue"),
            executed: counter("gaps_queue_executed_total", "Requests answered by executor rounds"),
            batches: counter("gaps_queue_batches_total", "search_batch rounds the executor ran"),
            coalesced: counter(
                "gaps_queue_coalesced_total",
                "Requests that shared their round with at least one other request",
            ),
            largest_batch: gauge(
                "gaps_queue_largest_batch",
                "Largest round drained so far (distinct queue slots)",
            ),
            singleflight: counter(
                "gaps_queue_singleflight_total",
                "Submissions attached to an identical already-pending request",
            ),
            shed: counter(
                "gaps_queue_shed_total",
                "Submissions rejected at the high-water mark (load shedding)",
            ),
            expired: counter(
                "gaps_queue_expired_total",
                "Requests whose deadline elapsed while queued",
            ),
            ingest_batches: counter(
                "gaps_queue_ingest_batches_total",
                "Ingest batches accepted into the ingestion lane",
            ),
            ingest_docs: counter(
                "gaps_queue_ingest_docs_total",
                "Publications accepted across all ingest batches",
            ),
            plan_hits: counter("gaps_cache_plan_hits_total", "Compiled-plan cache hits"),
            plan_misses: counter("gaps_cache_plan_misses_total", "Compiled-plan cache misses"),
            result_hits: counter("gaps_cache_result_hits_total", "Result-cache hits"),
            result_misses: counter("gaps_cache_result_misses_total", "Result-cache misses"),
            result_evicted: counter(
                "gaps_cache_result_evicted_total",
                "Result-cache entries dropped by capacity eviction",
            ),
            result_invalidated: counter(
                "gaps_cache_result_invalidated_total",
                "Result-cache entries dropped wholesale by index-epoch bumps",
            ),
            depth: gauge("gaps_queue_depth", "Requests currently pending in the queue"),
        }
    }

    /// Reassemble the legacy stats struct from the cells.
    fn snapshot(&self) -> QueueStats {
        QueueStats {
            submitted: self.submitted.get(),
            executed: self.executed.get(),
            batches: self.batches.get(),
            coalesced: self.coalesced.get(),
            largest_batch: self.largest_batch.get().max(0) as u64,
            singleflight: self.singleflight.get(),
            shed: self.shed.get(),
            expired: self.expired.get(),
            ingest_batches: self.ingest_batches.get(),
            ingest_docs: self.ingest_docs.get(),
            plan_hits: self.plan_hits.get(),
            plan_misses: self.plan_misses.get(),
            result_hits: self.result_hits.get(),
            result_misses: self.result_misses.get(),
            result_evicted: self.result_evicted.get(),
            result_invalidated: self.result_invalidated.get(),
        }
    }
}

/// The multi-user admission front over one executor-owned [`GapsSystem`].
///
/// Shared (`Arc`) between any number of submitting threads (HTTP
/// handlers, bench users) and exactly one executor loop ([`run`]).
pub struct AdmissionQueue {
    cfg: QueueConfig,
    inner: Mutex<Inner>,
    /// Signaled on every enqueue and on shutdown.
    arrived: Condvar,
    /// Registry-backed admission counters (see [`QueueMetrics`]).
    metrics: QueueMetrics,
}

/// The unified Retry-After hint (milliseconds) every shed path derives
/// from the same three inputs: the linger budget as the base wait, and
/// one extra base period per full round already waiting ahead of the
/// retrier. Replaces the two divergent constants the acceptor shed and
/// the queue high-water shed used to carry.
pub fn retry_after_hint(base_ms: u64, depth: usize, max_batch: usize) -> u64 {
    base_ms.max(1) * (1 + (depth / max_batch.max(1)) as u64)
}

/// A submitted request's pending response.
pub struct ResponseTicket {
    rx: mpsc::Receiver<Result<SearchResponse, SearchError>>,
}

impl ResponseTicket {
    /// Block until the coalesced round containing this request ran.
    pub fn wait(self) -> Result<SearchResponse, SearchError> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(SearchError::internal("serve executor is gone")))
    }
}

/// A submitted ingest batch's pending report.
pub struct IngestTicket {
    rx: mpsc::Receiver<Result<IngestReport, SearchError>>,
}

impl IngestTicket {
    /// Block until the executor ran (or failed) this ingest batch.
    pub fn wait(self) -> Result<IngestReport, SearchError> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(SearchError::internal("serve executor is gone")))
    }
}

/// A drained ingest round: one submitted batch of publications.
pub struct IngestBatch {
    docs: Vec<Publication>,
    reply: mpsc::Sender<Result<IngestReport, SearchError>>,
}

impl IngestBatch {
    /// Number of publications in the batch.
    pub fn len(&self) -> usize {
        self.docs.len()
    }

    /// Whether the batch is empty (a client may POST `{"docs": []}`).
    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }

    /// Move the publications out (the executor feeds them to
    /// [`GapsSystem::ingest`], then settles the ticket via
    /// [`IngestBatch::complete`]).
    pub fn take_docs(&mut self) -> Vec<Publication> {
        std::mem::take(&mut self.docs)
    }

    /// Deliver the batch's ingest report (or failure) to the submitter.
    /// A disconnected submitter is skipped silently.
    pub fn complete(self, result: Result<IngestReport, SearchError>) {
        let _ = self.reply.send(result);
    }
}

/// One executor round: either a coalesced search batch or an ingest
/// batch (see [`AdmissionQueue::next_round`]).
pub enum Round {
    /// A coalesced search round (exactly what [`AdmissionQueue::next_batch`]
    /// returns).
    Search(AdmittedBatch),
    /// One ingest batch, drained ahead of any search round.
    Ingest(IngestBatch),
}

/// A drained round: requests in deterministic (arrival) order.
pub struct AdmittedBatch {
    requests: Vec<SearchRequest>,
    replies: Vec<mpsc::Sender<Result<SearchResponse, SearchError>>>,
    /// Per-request single-flight attachments (parallel to `replies`):
    /// identical submissions that share the request's one execution.
    extra_replies: Vec<Vec<mpsc::Sender<Result<SearchResponse, SearchError>>>>,
    /// Per-request enqueue instants (parallel to `requests`) — the
    /// anchor of each request's `queued` trace span.
    arrivals: Vec<Instant>,
}

impl AdmittedBatch {
    /// The round's requests, in drain order.
    pub fn requests(&self) -> &[SearchRequest] {
        &self.requests
    }

    /// Seconds each request spent queued (arrival to now), in drain
    /// order. Measured once by the executor at round start.
    pub fn queued_seconds(&self) -> Vec<f64> {
        let now = Instant::now();
        self.arrivals.iter().map(|a| now.duration_since(*a).as_secs_f64()).collect()
    }

    /// Deliver the round's results (one per request, same order). A
    /// request's single-flight attachments each receive a clone of its
    /// result. Disconnected submitters (e.g. a dropped HTTP connection)
    /// are skipped silently.
    pub fn complete(self, results: Vec<Result<SearchResponse, SearchError>>) {
        debug_assert_eq!(self.replies.len(), results.len(), "one result per admitted request");
        for ((reply, extras), result) in
            self.replies.into_iter().zip(self.extra_replies).zip(results)
        {
            for extra in extras {
                let _ = extra.send(result.clone());
            }
            let _ = reply.send(result);
        }
    }
}

impl AdmissionQueue {
    /// An open queue with a private registry (standalone use: unit
    /// tests, benches). `max_batch` is clamped up to 1.
    pub fn new(cfg: QueueConfig) -> AdmissionQueue {
        AdmissionQueue::with_registry(cfg, &Registry::new(), None)
    }

    /// An open queue whose counters live in `registry` — the serving
    /// path, where `/metrics` scrapes every shard's queue from one
    /// place. `shard` becomes the cells' `shard` label (`None` for an
    /// unlabeled standalone queue).
    pub fn with_registry(
        mut cfg: QueueConfig,
        registry: &Registry,
        shard: Option<usize>,
    ) -> AdmissionQueue {
        cfg.max_batch = cfg.max_batch.max(1);
        AdmissionQueue {
            cfg,
            inner: Mutex::new(Inner {
                pending: VecDeque::new(),
                ingest_pending: VecDeque::new(),
                open: true,
                index_health: None,
            }),
            arrived: Condvar::new(),
            metrics: QueueMetrics::new(registry, shard),
        }
    }

    /// The configured coalescing knobs.
    pub fn config(&self) -> QueueConfig {
        self.cfg
    }

    /// Snapshot of the admission counters.
    pub fn stats(&self) -> QueueStats {
        self.metrics.snapshot()
    }

    /// Distinct pending search slots right now.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().pending.len()
    }

    /// This queue's Retry-After hint at its *current* depth (see
    /// [`retry_after_hint`]).
    pub fn retry_after_ms(&self) -> u64 {
        retry_after_hint(
            self.cfg.max_linger.as_millis().max(1) as u64,
            self.depth(),
            self.cfg.max_batch,
        )
    }

    /// Enqueue one request without blocking for its result.
    pub fn enqueue(&self, request: SearchRequest) -> ResponseTicket {
        self.enqueue_all(vec![request]).pop().expect("one ticket per request")
    }

    /// Enqueue several requests atomically (they occupy consecutive
    /// drain positions). Used by `POST /search_batch` so a user-provided
    /// batch cannot be interleaved with other users' requests. Requests
    /// beyond the high-water mark are shed individually (a batch that
    /// straddles the mark is admitted up to it).
    pub fn enqueue_all(&self, requests: Vec<SearchRequest>) -> Vec<ResponseTicket> {
        let mut tickets = Vec::with_capacity(requests.len());
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        let arrived = Instant::now();
        let base_ms = self.cfg.max_linger.as_millis().max(1) as u64;
        for request in requests {
            let (tx, rx) = mpsc::channel();
            if !inner.open {
                // Reject after shutdown: settle the ticket immediately
                // with a retryable availability error (the service is
                // draining, not broken).
                let _ = tx.send(Err(SearchError::unavailable("admission queue is shut down")));
            } else {
                // Single-flight: an identical request already waiting
                // shares its execution — attach this reply to it instead
                // of queueing a duplicate. Deadlined requests are exempt
                // (their expiry is anchored at each submission's own
                // arrival). Checked *before* the high-water mark, since
                // an attachment does not grow the queue.
                let flight = if request.deadline_ms.is_none() {
                    inner.pending.iter_mut().find(|p| p.request == request)
                } else {
                    None
                };
                match flight {
                    Some(p) => {
                        p.extra_replies.push(tx);
                        self.metrics.submitted.inc();
                        self.metrics.singleflight.inc();
                    }
                    None if inner.pending.len() >= self.cfg.max_depth => {
                        // Load shedding: fail fast at the high-water mark
                        // rather than queue unbounded latency. The hint
                        // scales with how much work is already waiting.
                        self.metrics.shed.inc();
                        let retry_after_ms =
                            retry_after_hint(base_ms, inner.pending.len(), self.cfg.max_batch);
                        let _ = tx.send(Err(SearchError::Overloaded { retry_after_ms }));
                    }
                    None => {
                        self.metrics.submitted.inc();
                        inner.pending.push_back(Pending {
                            request,
                            arrived,
                            reply: tx,
                            extra_replies: Vec::new(),
                        });
                        self.metrics.depth.set(inner.pending.len() as i64);
                    }
                }
            }
            tickets.push(ResponseTicket { rx });
        }
        drop(guard);
        self.arrived.notify_all();
        tickets
    }

    /// Submit one request and block until its coalesced round ran.
    pub fn submit(&self, request: SearchRequest) -> Result<SearchResponse, SearchError> {
        self.enqueue(request).wait()
    }

    /// Enqueue one ingest batch on the ingestion lane without blocking.
    /// The lane is not subject to the search high-water mark (writes are
    /// batched by the client and bounded by the HTTP body cap), but a
    /// shut-down queue rejects it with the same retryable availability
    /// error as a search submission.
    pub fn enqueue_ingest(&self, docs: Vec<Publication>) -> IngestTicket {
        let (tx, rx) = mpsc::channel();
        let mut inner = self.inner.lock().unwrap();
        if !inner.open {
            let _ = tx.send(Err(SearchError::unavailable("admission queue is shut down")));
        } else {
            self.metrics.ingest_batches.inc();
            self.metrics.ingest_docs.add(docs.len() as u64);
            inner.ingest_pending.push_back(IngestPending { docs, reply: tx });
        }
        drop(inner);
        self.arrived.notify_all();
        IngestTicket { rx }
    }

    /// Submit an ingest batch and block for its report.
    pub fn submit_ingest(&self, docs: Vec<Publication>) -> Result<IngestReport, SearchError> {
        self.enqueue_ingest(docs).wait()
    }

    /// Executor side: publish the system's index health after a round
    /// that changed it (deployment, seal, merge). Read back by
    /// `GET /healthz` via [`AdmissionQueue::index_health`].
    pub fn publish_index_health(&self, health: IndexHealth) {
        self.inner.lock().unwrap().index_health = Some(health);
    }

    /// Last published index health (`None` before the executor's first
    /// publication — e.g. on a queue with no executor attached).
    pub fn index_health(&self) -> Option<IndexHealth> {
        self.inner.lock().unwrap().index_health.clone()
    }

    /// Executor side: publish the plan-cache `(hits, misses)` and the
    /// result-cache counters into the stats snapshot. The values are
    /// absolute (the executor's caches own the counters); `GET /healthz`
    /// reads them back through [`AdmissionQueue::stats`].
    pub fn publish_cache_stats(&self, plan: (u64, u64), result: CacheCounters) {
        self.metrics.plan_hits.store(plan.0);
        self.metrics.plan_misses.store(plan.1);
        self.metrics.result_hits.store(result.hits);
        self.metrics.result_misses.store(result.misses);
        self.metrics.result_evicted.store(result.evicted);
        self.metrics.result_invalidated.store(result.invalidated);
    }

    /// Submit a pre-formed batch and block for all of its results
    /// (request order preserved).
    pub fn submit_batch(
        &self,
        requests: Vec<SearchRequest>,
    ) -> Vec<Result<SearchResponse, SearchError>> {
        self.enqueue_all(requests).into_iter().map(ResponseTicket::wait).collect()
    }

    /// Executor side: block for the next coalesced round. Returns `None`
    /// once the queue is shut down *and* drained — the executor's signal
    /// to exit. Requests whose deadline elapsed while queued are settled
    /// with `DeadlineExceeded` here and never reach the executor.
    pub fn next_batch(&self) -> Option<AdmittedBatch> {
        let mut inner = self.inner.lock().unwrap();
        'rounds: loop {
            loop {
                if !inner.pending.is_empty() {
                    break;
                }
                if !inner.open {
                    return None;
                }
                inner = self.arrived.wait(inner).unwrap();
            }

            // Linger for co-arrivals: up to `max_linger` past the *oldest*
            // pending request's arrival (a request never waits longer than
            // the linger budget, even if the executor was busy), or until a
            // full round is waiting.
            let deadline = inner.pending.front().expect("pending nonempty").arrived
                + self.cfg.max_linger;
            while inner.open && inner.pending.len() < self.cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) =
                    self.arrived.wait_timeout(inner, deadline - now).unwrap();
                inner = guard;
                if timeout.timed_out() {
                    break;
                }
            }

            let n = inner.pending.len().min(self.cfg.max_batch);
            let drained: Vec<Pending> = inner.pending.drain(..n).collect();
            self.metrics.depth.set(inner.pending.len() as i64);
            let mut requests = Vec::with_capacity(n);
            let mut replies = Vec::with_capacity(n);
            let mut extra_replies = Vec::with_capacity(n);
            let mut arrivals = Vec::with_capacity(n);
            for p in drained {
                let blown = p
                    .request
                    .deadline_ms
                    .map(|ms| p.arrived.elapsed() >= Duration::from_millis(ms))
                    .unwrap_or(false);
                if blown {
                    // Deadlined requests never carry single-flight
                    // attachments, so only one ticket settles here.
                    self.metrics.expired.inc();
                    let ms = p.request.deadline_ms.unwrap_or(0);
                    let _ = p.reply.send(Err(SearchError::DeadlineExceeded { deadline_ms: ms }));
                    continue;
                }
                requests.push(p.request);
                replies.push(p.reply);
                extra_replies.push(p.extra_replies);
                arrivals.push(p.arrived);
            }
            if requests.is_empty() {
                // Every drained request had expired in the queue; go back
                // to waiting (or exit, if shut down and drained).
                continue 'rounds;
            }
            let n = requests.len();
            let attached: usize = extra_replies.iter().map(Vec::len).sum();
            self.metrics.batches.inc();
            // Attachments are answered by this round too — `executed`
            // stays in lockstep with `submitted` — but they hold no
            // queue slot, so round-shape counters ignore them.
            self.metrics.executed.add((n + attached) as u64);
            if n >= 2 {
                self.metrics.coalesced.add(n as u64);
            }
            self.metrics.largest_batch.record_max(n as i64);
            return Some(AdmittedBatch { requests, replies, extra_replies, arrivals });
        }
    }

    /// Executor side: block for the next round of *either* lane. A
    /// pending ingest batch is returned first and without linger —
    /// writes never wait out a search coalescing window — then search
    /// rounds drain with exactly [`AdmissionQueue::next_batch`]'s
    /// semantics. Returns `None` once the queue is shut down and both
    /// lanes are drained.
    pub fn next_round(&self) -> Option<Round> {
        loop {
            {
                let mut inner = self.inner.lock().unwrap();
                loop {
                    if let Some(p) = inner.ingest_pending.pop_front() {
                        return Some(Round::Ingest(IngestBatch {
                            docs: p.docs,
                            reply: p.reply,
                        }));
                    }
                    if !inner.pending.is_empty() {
                        break;
                    }
                    if !inner.open {
                        return None;
                    }
                    inner = self.arrived.wait(inner).unwrap();
                }
            }
            // Search work is waiting: delegate to `next_batch` for the
            // full linger/expiry/drain logic (it re-takes the lock; an
            // ingest batch arriving inside the linger window runs next
            // round). `None` here means the search lane drained fully
            // expired after shutdown — loop to re-check the ingest lane.
            if let Some(batch) = self.next_batch() {
                return Some(Round::Search(batch));
            }
        }
    }

    /// Close the queue: new submissions are rejected, pending requests
    /// still drain, and [`AdmissionQueue::next_batch`] returns `None`
    /// once they have.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().open = false;
        self.arrived.notify_all();
    }

    /// Whether the queue still accepts submissions (`false` after
    /// [`AdmissionQueue::shutdown`]). The HTTP front uses this to stop
    /// idling on keep-alive connections once the service is draining:
    /// requests the client already pipelined are still answered (typed,
    /// by the closed queue itself), then the connection closes.
    pub fn is_open(&self) -> bool {
        self.inner.lock().unwrap().open
    }

    /// Executor-death path: close the queue AND fail every pending
    /// request immediately — nothing is left to run them, so letting
    /// them drain (or letting submitters block forever on tickets whose
    /// senders sit in the dead queue) would hang every client.
    fn abort(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.open = false;
        for p in inner.pending.drain(..) {
            for reply in std::iter::once(p.reply).chain(p.extra_replies) {
                let _ = reply.send(Err(SearchError::internal("serve executor terminated")));
            }
        }
        for p in inner.ingest_pending.drain(..) {
            let _ = p.reply.send(Err(SearchError::internal("serve executor terminated")));
        }
        self.metrics.depth.set(0);
        drop(inner);
        self.arrived.notify_all();
    }
}

/// The executor loop: drain rounds — coalesced search batches into
/// [`GapsSystem::search_batch`], ingest batches into
/// [`GapsSystem::ingest`] — until the queue shuts down. Runs on the
/// thread that owns the system (see [`super::SearchServer`]), so the
/// system itself never crosses a thread boundary. The system's
/// [`IndexHealth`] is published into the queue once at start and after
/// every ingest round (the only rounds that can move the index epoch).
///
/// **Life of a search round with caching:** every request is compiled
/// through the system's plan cache ([`GapsSystem::compile_request`]),
/// then probed against the executor-owned [`ResultCache`] under the
/// current index epoch. Hits are answered in place; only the misses
/// reach [`GapsSystem::search_batch`] (whose internal re-compilation is
/// a plan-cache hit, so a cold request compiles exactly once). Fresh
/// non-degraded successes are inserted for the next repeat. Because
/// search and ingest both run on this one thread, the epoch observed at
/// probe time is exact — an ingest round that moves it drops the whole
/// cache before any later search round can probe.
///
/// However the loop exits — normal shutdown or an unwinding panic from
/// the system — the queue is closed behind it and any still-pending
/// requests are failed, so submitters never block on an executor that
/// no longer exists. (After a clean shutdown-and-drain this is a
/// no-op.)
pub fn run(queue: &AdmissionQueue, sys: &mut GapsSystem) {
    run_with_obs(queue, sys, &ServeObs::default(), 0);
}

/// Failover counters as registry cells: absolute publishes from
/// [`GapsSystem::failover_stats`] after every search round (the system
/// owns the running totals).
struct FailoverCells {
    jobs_failed: Counter,
    replans: Counter,
    nodes_marked_down: Counter,
    probes: Counter,
    recoveries: Counter,
    degraded_responses: Counter,
}

impl FailoverCells {
    fn new(registry: &Registry, shard: &str) -> FailoverCells {
        let labels = [("shard", shard)];
        let c = |name: &str, help: &str| registry.counter_with(name, help, &labels);
        FailoverCells {
            jobs_failed: c(
                "gaps_failover_jobs_failed_total",
                "Per-node jobs that failed during a fan-out round",
            ),
            replans: c(
                "gaps_failover_replans_total",
                "Re-planning rounds triggered by failed jobs",
            ),
            nodes_marked_down: c(
                "gaps_failover_nodes_marked_down_total",
                "Nodes marked Down because one of their jobs failed",
            ),
            probes: c(
                "gaps_failover_probes_total",
                "Health probes issued to downed nodes whose probation elapsed",
            ),
            recoveries: c(
                "gaps_failover_recoveries_total",
                "Probes that came back healthy (node rejoined)",
            ),
            degraded_responses: c(
                "gaps_failover_degraded_responses_total",
                "Responses returned with degraded=true",
            ),
        }
    }

    fn publish(&self, s: &FailoverStats) {
        self.jobs_failed.store(s.jobs_failed);
        self.replans.store(s.replans);
        self.nodes_marked_down.store(s.nodes_marked_down);
        self.probes.store(s.probes);
        self.recoveries.store(s.recoveries);
        self.degraded_responses.store(s.degraded_responses);
    }
}

/// Index-health gauges/counters as registry cells: absolute publishes
/// from [`GapsSystem::index_health`] at start and after ingest rounds.
struct IndexCells {
    epoch: Gauge,
    searchable_docs: Gauge,
    buffered_docs: Gauge,
    segments: Gauge,
    seals: Counter,
    merges: Counter,
}

impl IndexCells {
    fn new(registry: &Registry, shard: &str) -> IndexCells {
        let labels = [("shard", shard)];
        IndexCells {
            epoch: registry.gauge_with(
                "gaps_index_epoch",
                "Index epoch (bumped by every seal and merge)",
                &labels,
            ),
            searchable_docs: registry.gauge_with(
                "gaps_index_searchable_docs",
                "Searchable documents (base corpus + sealed overlays)",
                &labels,
            ),
            buffered_docs: registry.gauge_with(
                "gaps_index_buffered_docs",
                "Ingested documents still buffered (unsearchable until their seal)",
                &labels,
            ),
            segments: registry.gauge_with(
                "gaps_index_segments",
                "Sealed overlay segments across all sources",
                &labels,
            ),
            seals: registry.counter_with(
                "gaps_index_seals_total",
                "Cumulative overlay seals",
                &labels,
            ),
            merges: registry.counter_with(
                "gaps_index_merges_total",
                "Cumulative overlay compaction merges",
                &labels,
            ),
        }
    }

    fn publish(&self, h: &IndexHealth) {
        self.epoch.set(h.epoch as i64);
        self.searchable_docs.set(h.searchable_docs as i64);
        self.buffered_docs.set(h.buffered_docs as i64);
        self.segments.set(h.segments.iter().map(|(_, n)| *n as i64).sum());
        self.seals.store(h.seals);
        self.merges.store(h.merges);
    }
}

/// [`run`] with observability: per-stage latency histograms, per-shard
/// failover/index cells, per-request trace trees (the `request` root
/// wrapping the coordinator's `search` subtree), and the slow-query
/// log. `shard` labels this executor's cells and spans.
///
/// Everything here is diagnostic: results delivered to submitters are
/// bit-identical to [`run`] without observability, except that each
/// successful response's `trace` (and `explain.stages`, when explain
/// was requested) carries the request's stage-timing tree.
pub fn run_with_obs(queue: &AdmissionQueue, sys: &mut GapsSystem, obs: &ServeObs, shard: usize) {
    struct AbortOnExit<'a>(&'a AdmissionQueue);
    impl Drop for AbortOnExit<'_> {
        fn drop(&mut self) {
            self.0.abort();
        }
    }
    let _guard = AbortOnExit(queue);
    let shard_label = shard.to_string();
    let stage_hist = |stage: &str| {
        obs.registry.histogram_with(
            "gaps_stage_seconds",
            "Request latency by lifecycle stage",
            LATENCY_BOUNDS_S,
            &[("stage", stage), ("shard", &shard_label)],
        )
    };
    let h_queued = stage_hist("queued");
    let h_probe = stage_hist("probe");
    let h_search = stage_hist("search");
    let h_compile = stage_hist("compile");
    let h_plan = stage_hist("plan");
    let h_execute = stage_hist("execute");
    let h_merge = stage_hist("merge");
    let h_store = stage_hist("store");
    let h_request = obs.registry.histogram_with(
        "gaps_request_seconds",
        "End-to-end request latency (queue arrival to settle)",
        LATENCY_BOUNDS_S,
        &[("shard", &shard_label)],
    );
    let slow_total = obs.registry.counter_with(
        "gaps_requests_slow_total",
        "Requests that crossed the obs.slow_query_ms threshold",
        &[("shard", &shard_label)],
    );
    let failover = FailoverCells::new(&obs.registry, &shard_label);
    let index_cells = IndexCells::new(&obs.registry, &shard_label);

    let health = sys.index_health();
    index_cells.publish(&health);
    queue.publish_index_health(health);
    failover.publish(&sys.failover_stats());
    let mut cache = ResultCache::new(&sys.cfg.cache);
    let mut epoch = sys.index_epoch();
    while let Some(round) = queue.next_round() {
        match round {
            Round::Search(batch) => {
                let round_clock = WallClock::start();
                let queued_s = batch.queued_seconds();
                let requests = batch.requests();
                let mut results: Vec<Option<Result<SearchResponse, SearchError>>> =
                    requests.iter().map(|_| None).collect();
                let mut fingerprints: Vec<u64> = vec![0; requests.len()];
                // Probe phase: compile (through the plan cache) and
                // answer result-cache hits without touching the grid.
                let probe_clock = WallClock::start();
                let mut miss_requests: Vec<SearchRequest> = Vec::new();
                let mut miss_slots: Vec<(usize, Option<CompiledRequest>)> = Vec::new();
                for (i, req) in requests.iter().enumerate() {
                    match sys.compile_request(req) {
                        Ok(compiled) => {
                            fingerprints[i] = compiled.fingerprint;
                            match cache.get(&compiled, epoch) {
                                Some(mut resp) => {
                                    // The entry may have been written by an
                                    // equivalent-but-reordered query; echo
                                    // *this* submitter's raw text, exactly
                                    // as cold execution would.
                                    resp.query = req.query.clone();
                                    results[i] = Some(Ok(resp));
                                }
                                None => {
                                    miss_requests.push(req.clone());
                                    miss_slots.push((i, Some(compiled)));
                                }
                            }
                        }
                        // Uncompilable requests take the miss path so
                        // the error a submitter sees is exactly the one
                        // `search_batch` produces.
                        Err(_) => {
                            miss_requests.push(req.clone());
                            miss_slots.push((i, None));
                        }
                    }
                }
                let probe_s = probe_clock.elapsed_s();
                // Execute phase: only the misses reach the grid.
                let mut store_s = 0.0f64;
                if !miss_requests.is_empty() {
                    let executed = sys.search_batch(&miss_requests);
                    let store_clock = WallClock::start();
                    for ((i, compiled), result) in miss_slots.into_iter().zip(executed) {
                        if let (Some(compiled), Ok(resp)) = (&compiled, &result) {
                            // Degraded responses rank only the reachable
                            // corpus — never cache them.
                            if !resp.degraded {
                                // The stored copy drops its trace: stage
                                // timings describe one execution, and a
                                // later hit gets its own request tree.
                                let mut entry = resp.clone();
                                entry.trace = None;
                                cache.insert(compiled, epoch, entry);
                            }
                        }
                        results[i] = Some(result);
                    }
                    store_s = store_clock.elapsed_s();
                }
                queue.publish_cache_stats(sys.plan_cache_stats(), cache.counters());
                failover.publish(&sys.failover_stats());

                // Trace assembly, stage histograms, and the slow log —
                // one `request` root per settled slot.
                let round_s = round_clock.elapsed_s();
                let mut final_results = Vec::with_capacity(results.len());
                for (i, settled) in results.into_iter().enumerate() {
                    let mut settled = settled.expect("every slot settled");
                    let queued = queued_s.get(i).copied().unwrap_or(0.0);
                    let total_s = queued + round_s;
                    let mut root = TraceSpan::new("request", total_s)
                        .with_meta("shard", shard_label.clone());
                    root.push_child(TraceSpan::new("queued", queued));
                    root.push_child(TraceSpan::new("probe", probe_s));
                    match &mut settled {
                        Ok(resp) => {
                            match resp.trace.take() {
                                Some(search_span) => {
                                    h_search.observe(search_span.seconds);
                                    if let Some(s) = search_span.find("compile") {
                                        h_compile.observe(s.seconds);
                                    }
                                    if let Some(s) = search_span.find("plan") {
                                        h_plan.observe(s.seconds);
                                    }
                                    if let Some(s) = search_span.find("execute") {
                                        h_execute.observe(s.seconds);
                                    }
                                    if let Some(s) = search_span.find("merge") {
                                        h_merge.observe(s.seconds);
                                    }
                                    root.push_child(search_span);
                                }
                                // A result-cache hit never reached the
                                // grid: no `search` child, marked on
                                // the root instead.
                                None => root.meta.push((
                                    "result_cache".to_string(),
                                    "hit".to_string(),
                                )),
                            }
                            root.push_child(TraceSpan::new("store", store_s));
                            resp.trace = Some(root.clone());
                            if let Some(e) = resp.explain.as_mut() {
                                e.stages = Some(root.clone());
                            }
                        }
                        Err(_) => root.push_child(TraceSpan::new("store", store_s)),
                    }
                    h_queued.observe(queued);
                    h_probe.observe(probe_s);
                    h_store.observe(store_s);
                    h_request.observe(total_s);
                    if total_s * 1e3 >= obs.slow_query_ms as f64 {
                        slow_total.inc();
                        let (degraded, error, counters) = match &settled {
                            Ok(resp) => (
                                resp.degraded,
                                None,
                                resp.explain.as_ref().map(|e| counters_to_json(&e.counters)),
                            ),
                            Err(e) => (false, Some(e.kind().to_string()), None),
                        };
                        obs.slow.record(SlowEntry {
                            fingerprint: fingerprints[i],
                            query: requests[i].query.clone(),
                            shard,
                            epoch,
                            total_s,
                            degraded,
                            error,
                            counters,
                            stages: Some(root.clone()),
                        });
                    }
                    final_results.push(settled);
                }
                batch.complete(final_results);
            }
            Round::Ingest(mut batch) => {
                let report = sys.ingest(batch.take_docs());
                let now = sys.index_epoch();
                if now != epoch {
                    // The epoch moved (a segment sealed or merged):
                    // every cached result is keyed on the old epoch and
                    // is stale at once — drop them all.
                    cache.invalidate_all();
                    epoch = now;
                }
                queue.publish_cache_stats(sys.plan_cache_stats(), cache.counters());
                let health = sys.index_health();
                index_cells.publish(&health);
                queue.publish_index_health(health);
                batch.complete(Ok(report));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queue(max_batch: usize, linger: Duration) -> AdmissionQueue {
        AdmissionQueue::new(QueueConfig {
            max_batch,
            max_linger: linger,
            ..QueueConfig::default()
        })
    }

    fn req(i: usize) -> SearchRequest {
        SearchRequest::new(format!("query {i}"))
    }

    #[test]
    fn drains_fifo_in_max_batch_chunks() {
        // 5 queued, max_batch 3 -> rounds of [0,1,2] then [3,4].
        let q = queue(3, Duration::ZERO);
        let _tickets: Vec<_> = (0..5).map(|i| q.enqueue(req(i))).collect();
        let first = q.next_batch().expect("first round");
        let texts: Vec<&str> = first.requests().iter().map(|r| r.query.as_str()).collect();
        assert_eq!(texts, ["query 0", "query 1", "query 2"]);
        let second = q.next_batch().expect("second round");
        let texts: Vec<&str> = second.requests().iter().map(|r| r.query.as_str()).collect();
        assert_eq!(texts, ["query 3", "query 4"]);
        let stats = q.stats();
        assert_eq!(stats.submitted, 5);
        assert_eq!(stats.executed, 5);
        assert_eq!(stats.batches, 2);
        assert_eq!(stats.coalesced, 5);
        assert_eq!(stats.largest_batch, 3);
    }

    #[test]
    fn max_batch_one_never_coalesces() {
        let q = queue(1, Duration::from_secs(60));
        let _t0 = q.enqueue(req(0));
        let _t1 = q.enqueue(req(1));
        // A full round is already waiting, so next_batch must not linger
        // (the 60s budget would hang the test if it did).
        for expect in ["query 0", "query 1"] {
            let b = q.next_batch().expect("round");
            assert_eq!(b.requests().len(), 1);
            assert_eq!(b.requests()[0].query, expect);
        }
        assert_eq!(q.stats().coalesced, 0);
        assert_eq!(q.stats().largest_batch, 1);
    }

    #[test]
    fn full_round_skips_linger() {
        // Exactly max_batch pending: the drain must return immediately
        // even with an hour of linger budget.
        let q = queue(4, Duration::from_secs(3600));
        let _tickets: Vec<_> = (0..4).map(|i| q.enqueue(req(i))).collect();
        let b = q.next_batch().expect("round");
        assert_eq!(b.requests().len(), 4);
    }

    #[test]
    fn zero_linger_drains_what_is_queued() {
        let q = queue(16, Duration::ZERO);
        let _t0 = q.enqueue(req(0));
        let _t1 = q.enqueue(req(1));
        let b = q.next_batch().expect("round");
        assert_eq!(b.requests().len(), 2, "both were already queued");
    }

    #[test]
    fn expired_linger_drains_immediately() {
        // The linger window is anchored at the oldest *arrival*: if the
        // executor shows up late, the deadline is already past.
        let q = queue(16, Duration::from_millis(200));
        let _t = q.enqueue(req(0));
        std::thread::sleep(Duration::from_millis(250));
        let t = Instant::now();
        let b = q.next_batch().expect("round");
        assert_eq!(b.requests().len(), 1);
        // A buggy drain that anchors the window at drain time would wait
        // the full 200ms here; the correct one returns at once.
        assert!(t.elapsed() < Duration::from_millis(150), "lingered past the deadline");
    }

    #[test]
    fn linger_collects_late_arrivals() {
        // A request arriving inside the window joins the round (the
        // drain waits out the whole window since max_batch stays out of
        // reach, so keep the window short).
        let q = AdmissionQueue::new(QueueConfig {
            max_batch: 8,
            max_linger: Duration::from_millis(300),
            ..QueueConfig::default()
        });
        let _t0 = q.enqueue(req(0));
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(20));
                let _t1 = q.enqueue(req(1));
            });
            let b = q.next_batch().expect("round");
            assert_eq!(b.requests().len(), 2, "late arrival missed the round");
        });
    }

    #[test]
    fn shutdown_drains_then_ends() {
        let q = queue(2, Duration::ZERO);
        let _tickets: Vec<_> = (0..3).map(|i| q.enqueue(req(i))).collect();
        q.shutdown();
        assert_eq!(q.next_batch().expect("round").requests().len(), 2);
        assert_eq!(q.next_batch().expect("round").requests().len(), 1);
        assert!(q.next_batch().is_none(), "drained + closed means None");
        assert!(q.next_batch().is_none(), "None is sticky");
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        // A draining queue is *unavailable* (retryable 503), not an
        // internal fault: clients and load balancers treat the two very
        // differently.
        let q = queue(4, Duration::ZERO);
        q.shutdown();
        let err = q.submit(req(0)).expect_err("closed queue must reject");
        assert_eq!(err.kind(), "unavailable");
        assert_eq!(q.stats().submitted, 0);
    }

    #[test]
    fn is_open_tracks_shutdown() {
        let q = queue(4, Duration::ZERO);
        assert!(q.is_open());
        q.shutdown();
        assert!(!q.is_open());
    }

    #[test]
    fn absorb_sums_counters_and_maxes_the_high_water_mark() {
        let mut total = QueueStats { submitted: 3, executed: 3, largest_batch: 2, ..QueueStats::default() };
        let other = QueueStats {
            submitted: 5,
            executed: 4,
            batches: 2,
            coalesced: 2,
            largest_batch: 7,
            singleflight: 1,
            shed: 1,
            expired: 1,
            ingest_batches: 1,
            ingest_docs: 9,
            plan_hits: 2,
            plan_misses: 3,
            result_hits: 4,
            result_misses: 5,
            result_evicted: 1,
            result_invalidated: 6,
        };
        total.absorb(&other);
        assert_eq!(total.submitted, 8);
        assert_eq!(total.executed, 7);
        assert_eq!(total.batches, 2);
        assert_eq!(total.coalesced, 2);
        assert_eq!(total.largest_batch, 7, "high-water mark takes the max, not the sum");
        assert_eq!(total.singleflight, 1);
        assert_eq!(total.shed, 1);
        assert_eq!(total.expired, 1);
        assert_eq!(total.ingest_batches, 1);
        assert_eq!(total.ingest_docs, 9);
        assert_eq!(total.plan_hits, 2);
        assert_eq!(total.plan_misses, 3);
        assert_eq!(total.result_hits, 4);
        assert_eq!(total.result_misses, 5);
        assert_eq!(total.result_evicted, 1);
        assert_eq!(total.result_invalidated, 6);

        // Absorbing into a fresh default reproduces the source exactly.
        let mut fresh = QueueStats::default();
        fresh.absorb(&other);
        assert_eq!(fresh, other);
    }

    #[test]
    fn overload_sheds_beyond_max_depth() {
        let q = AdmissionQueue::new(QueueConfig {
            max_batch: 4,
            max_linger: Duration::from_millis(7),
            max_depth: 2,
        });
        let _t0 = q.enqueue(req(0));
        let _t1 = q.enqueue(req(1));
        let shed = q.enqueue(req(2));
        let err = shed.wait().expect_err("beyond the high-water mark must shed");
        assert_eq!(err.kind(), "overloaded");
        match err {
            SearchError::Overloaded { retry_after_ms } => assert_eq!(retry_after_ms, 7),
            other => panic!("unexpected error {other:?}"),
        }
        let stats = q.stats();
        assert_eq!(stats.submitted, 2, "shed requests are not admissions");
        assert_eq!(stats.shed, 1);
        // Draining frees capacity again.
        q.next_batch().expect("round");
        let _t3 = q.enqueue(req(3));
        assert_eq!(q.stats().submitted, 3);
        assert_eq!(q.stats().shed, 1);
    }

    #[test]
    fn queued_past_deadline_settles_without_executing() {
        let q = queue(4, Duration::ZERO);
        let t_dead = q.enqueue(SearchRequest::new("stale").deadline_ms(1));
        let t_live = q.enqueue(SearchRequest::new("fresh"));
        std::thread::sleep(Duration::from_millis(20));
        let b = q.next_batch().expect("round");
        assert_eq!(b.requests().len(), 1, "expired request reached the executor");
        assert_eq!(b.requests()[0].query, "fresh");
        b.complete(vec![Err(SearchError::parse("x"))]);
        let e = t_dead.wait().expect_err("deadline blew in the queue");
        assert_eq!(e.kind(), "deadline-exceeded");
        assert!(t_live.wait().is_err(), "live ticket still settles");
        let stats = q.stats();
        assert_eq!(stats.expired, 1);
        assert_eq!(stats.executed, 1, "only the live request executed");
    }

    #[test]
    fn fully_expired_round_does_not_stall_shutdown() {
        let q = queue(4, Duration::ZERO);
        let t = q.enqueue(SearchRequest::new("stale").deadline_ms(1));
        std::thread::sleep(Duration::from_millis(10));
        q.shutdown();
        assert!(q.next_batch().is_none(), "expired round must not hang the drain");
        assert_eq!(t.wait().expect_err("expired").kind(), "deadline-exceeded");
        assert_eq!(q.stats().expired, 1);
    }

    #[test]
    fn complete_settles_tickets_in_order() {
        let q = queue(8, Duration::ZERO);
        let tickets: Vec<_> = (0..3).map(|i| q.enqueue(req(i))).collect();
        let batch = q.next_batch().expect("round");
        let n = batch.requests().len();
        // Fabricate per-request outcomes without a deployed system.
        let results =
            (0..n).map(|i| Err(SearchError::parse(format!("result {i}")))).collect();
        batch.complete(results);
        for (i, t) in tickets.into_iter().enumerate() {
            let e = t.wait().expect_err("fabricated error result");
            assert!(e.to_string().contains(&format!("result {i}")), "ticket order broken");
        }
    }

    #[test]
    fn dropped_ticket_does_not_poison_the_round() {
        let q = queue(8, Duration::ZERO);
        let t0 = q.enqueue(req(0));
        let t1 = q.enqueue(req(1));
        drop(t0); // submitter went away (e.g. closed HTTP connection)
        let batch = q.next_batch().expect("round");
        batch.complete(vec![
            Err(SearchError::parse("a")),
            Err(SearchError::parse("b")),
        ]);
        assert!(t1.wait().is_err(), "surviving ticket still settles");
    }

    #[test]
    fn stats_json_carries_all_counters() {
        let q = queue(4, Duration::ZERO);
        let _t: Vec<_> = (0..2).map(|i| q.enqueue(req(i))).collect();
        q.next_batch().expect("round");
        q.publish_cache_stats((3, 4), CacheCounters {
            hits: 5,
            misses: 6,
            evicted: 7,
            invalidated: 8,
        });
        let j = q.stats().to_json();
        assert_eq!(j.get("submitted").unwrap().as_i64(), Some(2));
        assert_eq!(j.get("batches").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("coalesced").unwrap().as_i64(), Some(2));
        assert_eq!(j.get("largest_batch").unwrap().as_i64(), Some(2));
        assert_eq!(j.get("shed").unwrap().as_i64(), Some(0));
        assert_eq!(j.get("expired").unwrap().as_i64(), Some(0));
        assert_eq!(j.get("singleflight").unwrap().as_i64(), Some(0));
        assert_eq!(j.get("plan_hits").unwrap().as_i64(), Some(3));
        assert_eq!(j.get("plan_misses").unwrap().as_i64(), Some(4));
        assert_eq!(j.get("result_hits").unwrap().as_i64(), Some(5));
        assert_eq!(j.get("result_misses").unwrap().as_i64(), Some(6));
        assert_eq!(j.get("result_evicted").unwrap().as_i64(), Some(7));
        assert_eq!(j.get("result_invalidated").unwrap().as_i64(), Some(8));
    }

    #[test]
    fn identical_pending_requests_share_one_flight() {
        let q = queue(8, Duration::ZERO);
        let t0 = q.enqueue(SearchRequest::new("grid computing"));
        let t1 = q.enqueue(SearchRequest::new("grid computing"));
        let t2 = q.enqueue(SearchRequest::new("cloud storage"));
        let b = q.next_batch().expect("round");
        assert_eq!(b.requests().len(), 2, "the duplicate must not occupy a queue slot");
        b.complete(vec![
            Err(SearchError::parse("grid result")),
            Err(SearchError::parse("cloud result")),
        ]);
        // Both submitters of the coalesced query get the one result.
        for t in [t0, t1] {
            let e = t.wait().expect_err("fabricated result");
            assert!(e.to_string().contains("grid result"), "{e}");
        }
        let e = t2.wait().expect_err("fabricated result");
        assert!(e.to_string().contains("cloud result"), "{e}");
        let stats = q.stats();
        assert_eq!(stats.submitted, 3);
        assert_eq!(stats.singleflight, 1);
        assert_eq!(stats.executed, 3, "the attachment counts as answered");
        assert_eq!(stats.largest_batch, 2, "attachments do not grow the round shape");
    }

    #[test]
    fn different_knobs_do_not_share_a_flight() {
        // Same query text, different result-affecting knob: full
        // request equality gates single-flight, so these stay separate.
        let q = queue(8, Duration::ZERO);
        let _t0 = q.enqueue(SearchRequest::new("grid").top_k(3));
        let _t1 = q.enqueue(SearchRequest::new("grid").top_k(7));
        let b = q.next_batch().expect("round");
        assert_eq!(b.requests().len(), 2);
        assert_eq!(q.stats().singleflight, 0);
    }

    #[test]
    fn deadlined_requests_do_not_coalesce() {
        // Expiry is anchored at each submission's own arrival; sharing
        // a flight would give the attachment the primary's deadline.
        let q = queue(8, Duration::ZERO);
        let _t0 = q.enqueue(SearchRequest::new("grid").deadline_ms(60_000));
        let _t1 = q.enqueue(SearchRequest::new("grid").deadline_ms(60_000));
        let b = q.next_batch().expect("round");
        assert_eq!(b.requests().len(), 2);
        assert_eq!(q.stats().singleflight, 0);
    }

    #[test]
    fn singleflight_absorbs_duplicates_even_at_the_high_water_mark() {
        let q = AdmissionQueue::new(QueueConfig {
            max_batch: 4,
            max_linger: Duration::ZERO,
            max_depth: 1,
        });
        let _t0 = q.enqueue(SearchRequest::new("grid"));
        // The queue is full, but an identical request attaches instead
        // of growing it — no shed.
        let _t1 = q.enqueue(SearchRequest::new("grid"));
        // A *different* request at the mark is shed as before.
        let t2 = q.enqueue(SearchRequest::new("cloud"));
        assert_eq!(t2.wait().expect_err("over the mark").kind(), "overloaded");
        let stats = q.stats();
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.singleflight, 1);
        assert_eq!(stats.shed, 1);
    }

    #[test]
    fn abort_fails_singleflight_attachments_too() {
        let q = queue(8, Duration::ZERO);
        let t0 = q.enqueue(SearchRequest::new("grid"));
        let t1 = q.enqueue(SearchRequest::new("grid"));
        q.abort();
        for t in [t0, t1] {
            assert_eq!(t.wait().expect_err("aborted").kind(), "internal");
        }
    }

    #[test]
    fn abort_fails_pending_and_closes() {
        // The executor-death path: pending tickets settle with an error
        // instead of hanging, and the queue stays closed.
        let q = queue(8, Duration::ZERO);
        let t0 = q.enqueue(req(0));
        let t1 = q.enqueue(req(1));
        q.abort();
        for t in [t0, t1] {
            let e = t.wait().expect_err("aborted ticket must fail");
            assert_eq!(e.kind(), "internal");
        }
        assert!(q.next_batch().is_none(), "aborted queue has no rounds");
        assert!(q.submit(req(2)).is_err(), "aborted queue rejects submissions");
    }

    #[test]
    fn max_batch_zero_is_clamped() {
        let q = queue(0, Duration::ZERO);
        assert_eq!(q.config().max_batch, 1);
    }

    fn doc(i: u64) -> Publication {
        Publication {
            id: i,
            title: format!("ingested doc {i}"),
            abstract_text: "live ingestion exercises the second lane".into(),
            authors: "A. Author".into(),
            venue: "TEST".into(),
            year: 2026,
        }
    }

    #[test]
    fn ingest_rounds_drain_before_search() {
        // A search request arrives first, an ingest batch second — the
        // ingest batch still runs first (writes skip the linger window).
        let q = queue(8, Duration::ZERO);
        let _search = q.enqueue(req(0));
        let _ingest = q.enqueue_ingest(vec![doc(0), doc(1)]);
        match q.next_round().expect("round") {
            Round::Ingest(b) => assert_eq!(b.len(), 2),
            Round::Search(_) => panic!("ingest must preempt search"),
        }
        match q.next_round().expect("round") {
            Round::Search(b) => assert_eq!(b.requests().len(), 1),
            Round::Ingest(_) => panic!("ingest lane should be drained"),
        }
        let stats = q.stats();
        assert_eq!(stats.ingest_batches, 1);
        assert_eq!(stats.ingest_docs, 2);
        assert_eq!(stats.executed, 1, "search counters unaffected by ingest");
    }

    #[test]
    fn ingest_round_settles_its_ticket() {
        let q = queue(4, Duration::ZERO);
        let ticket = q.enqueue_ingest(vec![doc(7)]);
        let round = q.next_round().expect("round");
        let Round::Ingest(mut batch) = round else { panic!("expected ingest round") };
        assert!(!batch.is_empty());
        let docs = batch.take_docs();
        assert_eq!(docs.len(), 1);
        assert_eq!(docs[0].title, "ingested doc 7");
        batch.complete(Ok(IngestReport { accepted: 1, epoch: 3, ..IngestReport::default() }));
        let report = ticket.wait().expect("report");
        assert_eq!(report.accepted, 1);
        assert_eq!(report.epoch, 3);
    }

    #[test]
    fn next_round_drains_then_ends_after_shutdown() {
        let q = queue(4, Duration::ZERO);
        let _t = q.enqueue(req(0));
        let _i = q.enqueue_ingest(vec![doc(0)]);
        q.shutdown();
        assert!(matches!(q.next_round(), Some(Round::Ingest(_))));
        assert!(matches!(q.next_round(), Some(Round::Search(_))));
        assert!(q.next_round().is_none(), "both lanes drained + closed means None");
    }

    #[test]
    fn ingest_after_shutdown_is_rejected() {
        let q = queue(4, Duration::ZERO);
        q.shutdown();
        let err = q.submit_ingest(vec![doc(0)]).expect_err("closed queue must reject");
        assert_eq!(err.kind(), "unavailable");
        assert_eq!(q.stats().ingest_batches, 0);
    }

    #[test]
    fn abort_fails_pending_ingest() {
        let q = queue(4, Duration::ZERO);
        let t = q.enqueue_ingest(vec![doc(0)]);
        q.abort();
        assert_eq!(t.wait().expect_err("aborted").kind(), "internal");
    }

    #[test]
    fn index_health_cell_publishes_and_reads_back() {
        let q = queue(4, Duration::ZERO);
        assert!(q.index_health().is_none(), "no executor has published yet");
        let health = IndexHealth {
            epoch: 5,
            searchable_docs: 640,
            buffered_docs: 3,
            segments: vec![(0, 2), (4, 1)],
            seals: 4,
            merges: 1,
        };
        q.publish_index_health(health.clone());
        assert_eq!(q.index_health(), Some(health));
    }

    #[test]
    fn stats_json_carries_ingest_counters() {
        let q = queue(4, Duration::ZERO);
        let _t = q.enqueue_ingest(vec![doc(0), doc(1), doc(2)]);
        let j = q.stats().to_json();
        assert_eq!(j.get("ingest_batches").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("ingest_docs").unwrap().as_i64(), Some(3));
    }
}
