//! Shard router: fans the serving layer out over N executor shards.
//!
//! PR 5/8 built the serving layer around exactly one executor thread —
//! correct, but every scoring round serialized behind it. The router
//! generalizes that to N **replica shards**: each shard is its own
//! [`AdmissionQueue`] drained by its own executor thread owning its own
//! `GapsSystem` (the system is `!Send`, so one-system-per-thread is the
//! only shape that works with thread-pinned scoring runtimes). Round
//! execution on one shard overlaps linger windows on the others.
//!
//! **Search dispatch** is round-robin: each submission lands on the
//! next shard in rotation. Because every shard is a deterministic
//! replica of the same deployment, *which* shard answers is invisible
//! in the response — sharded serving stays bit-identical to a
//! single-shard serial oracle (`tests/prop_serve_parity.rs`).
//!
//! **Ingest dispatch** fans out to *every* shard under one lock, so all
//! replicas apply the same writes in the same order and their index
//! epochs move in lockstep. Each shard's executor drops its own result
//! cache when it observes the epoch bump, which keeps the per-shard
//! caches coherent without any cross-shard invalidation protocol (see
//! [`super::cache`]).
//!
//! The router also owns the HTTP front's connection counters
//! ([`HttpCounters`]): accepted/active/shed connections and
//! served/reused request counts, published on `GET /healthz` next to
//! the per-shard admission stats.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::{IndexHealth, IngestReport, SearchResponse};
use crate::corpus::Publication;
use crate::obs::{Counter, Gauge, Registry};
use crate::search::{SearchError, SearchRequest};
use crate::util::json::Json;

use super::queue::{AdmissionQueue, QueueStats};
use super::ServeObs;

/// Snapshot of the HTTP front's connection counters (the `/healthz`
/// `http` object).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HttpStats {
    /// Connections accepted into the handler pool.
    pub accepted: u64,
    /// Connections currently held by a handler (keep-alive connections
    /// count until they close, not just while a request is in flight).
    pub active: u64,
    /// Connections refused at the acceptor because every handler was
    /// busy (answered with a complete 503 + `Retry-After`, then closed).
    pub shed: u64,
    /// Requests served across all connections.
    pub requests: u64,
    /// Requests served on an already-used connection — the observable
    /// evidence of keep-alive reuse.
    pub reused: u64,
}

impl HttpStats {
    /// JSON form (the `/healthz` `http` object).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("accepted", Json::from(self.accepted)),
            ("active", Json::from(self.active)),
            ("shed", Json::from(self.shed)),
            ("requests", Json::from(self.requests)),
            ("reused", Json::from(self.reused)),
        ])
    }
}

/// Live connection counters for the HTTP front. The acceptor gates on
/// `active` (connections beyond the handler-pool size are shed), the
/// handlers count requests, and `GET /healthz` snapshots the lot. The
/// counters are [`Registry`] cells, so the same numbers appear under
/// `gaps_http_*` on `GET /metrics` and can be frozen together with the
/// per-shard admission counters for an atomically consistent `/healthz`.
#[derive(Debug)]
pub struct HttpCounters {
    accepted: Counter,
    active: Gauge,
    shed: Counter,
    requests: Counter,
    reused: Counter,
}

impl Default for HttpCounters {
    /// Counters backed by a private throwaway registry (tests and the
    /// non-observability constructors).
    fn default() -> HttpCounters {
        HttpCounters::new(&Registry::new())
    }
}

impl HttpCounters {
    /// Register the `gaps_http_*` family on `registry` and return the
    /// live cells.
    pub fn new(registry: &Registry) -> HttpCounters {
        HttpCounters {
            accepted: registry.counter(
                "gaps_http_accepted_total",
                "Connections accepted into the handler pool.",
            ),
            active: registry.gauge(
                "gaps_http_active",
                "Connections currently held by a handler.",
            ),
            shed: registry.counter(
                "gaps_http_shed_total",
                "Connections refused at the acceptor because every handler was busy.",
            ),
            requests: registry.counter(
                "gaps_http_requests_total",
                "Requests served across all connections.",
            ),
            reused: registry.counter(
                "gaps_http_reused_total",
                "Requests served on an already-used (keep-alive) connection.",
            ),
        }
    }

    /// Connections currently held by handlers.
    pub fn active(&self) -> u64 {
        self.active.get().max(0) as u64
    }

    /// Snapshot every counter.
    pub fn stats(&self) -> HttpStats {
        HttpStats {
            accepted: self.accepted.get(),
            active: self.active.get().max(0) as u64,
            shed: self.shed.get(),
            requests: self.requests.get(),
            reused: self.reused.get(),
        }
    }

    /// Acceptor side: a connection enters the handler pool.
    pub(crate) fn begin_connection(&self) {
        self.accepted.inc();
        self.active.add(1);
    }

    /// Handler side: a connection's handler finished (however it ended).
    pub(crate) fn end_connection(&self) {
        self.active.sub(1);
    }

    /// Acceptor side: a connection was refused at the pool bound.
    pub(crate) fn shed_connection(&self) {
        self.shed.inc();
    }

    /// Handler side: one request was served on a connection; `reused`
    /// marks requests after the first on the same socket.
    pub(crate) fn count_request(&self, reused: bool) {
        self.requests.inc();
        if reused {
            self.reused.inc();
        }
    }
}

/// Point-in-time view of the whole serving plane, taken under one
/// registry freeze so the queue, HTTP, and index numbers are mutually
/// consistent (satellite fix: `/healthz` previously read each family
/// separately and could observe a shard's `submitted` bump without the
/// HTTP `requests` bump that preceded it in program order).
#[derive(Debug, Clone)]
pub struct HealthSnapshot {
    /// Aggregate admission counters (sums; `largest_batch` is a max).
    pub queue: QueueStats,
    /// Per-shard admission counters, in shard order.
    pub per_shard: Vec<QueueStats>,
    /// HTTP front counters.
    pub http: HttpStats,
    /// Index health from shard 0's executor, if published yet.
    pub index: Option<IndexHealth>,
}

/// Round-robin front over N executor shards (each an [`AdmissionQueue`]
/// drained by its own executor thread). One shard degenerates to the
/// pre-sharding behaviour exactly.
pub struct ShardRouter {
    shards: Vec<Arc<AdmissionQueue>>,
    /// Rotation cursor for search dispatch.
    next: AtomicUsize,
    /// Serializes ingest fan-out: every shard must observe the same
    /// writes in the same order, or the replicas (and their epochs)
    /// diverge.
    ingest_lock: Mutex<()>,
    http: HttpCounters,
    /// Observability plumbing shared with the executors: the registry
    /// `GET /metrics` renders and the slow-query ring `GET /debug/slow`
    /// dumps.
    obs: ServeObs,
}

impl ShardRouter {
    /// A router over the given shards (at least one), with a private
    /// observability sink (tests, embedded use).
    pub fn new(shards: Vec<Arc<AdmissionQueue>>) -> ShardRouter {
        ShardRouter::with_obs(shards, ServeObs::default())
    }

    /// A router wired to a shared observability sink. Pass the same
    /// [`ServeObs`] the shards' queues were registered on so `GET
    /// /metrics` sees the whole serving plane.
    pub fn with_obs(shards: Vec<Arc<AdmissionQueue>>, obs: ServeObs) -> ShardRouter {
        assert!(!shards.is_empty(), "router needs at least one shard");
        ShardRouter {
            shards,
            next: AtomicUsize::new(0),
            ingest_lock: Mutex::new(()),
            http: HttpCounters::new(&obs.registry),
            obs,
        }
    }

    /// A single-shard router (the pre-sharding serving shape).
    pub fn single(queue: Arc<AdmissionQueue>) -> ShardRouter {
        ShardRouter::new(vec![queue])
    }

    /// Number of executor shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// A shard's admission queue by index.
    pub fn shard(&self, i: usize) -> &Arc<AdmissionQueue> {
        &self.shards[i]
    }

    /// The HTTP front's connection counters.
    pub fn http(&self) -> &HttpCounters {
        &self.http
    }

    /// The observability sink this router (and its shards' executors)
    /// publish into.
    pub fn obs(&self) -> &ServeObs {
        &self.obs
    }

    /// `Retry-After` hint for the acceptor's shed response: the worst
    /// (deepest-backlog) shard's hint, so a retrying client waits long
    /// enough for rotation to find it a free shard. See
    /// [`super::retry_after_hint`] for the formula.
    pub fn retry_after_ms(&self) -> u64 {
        self.shards
            .iter()
            .map(|q| q.retry_after_ms())
            .max()
            .unwrap_or(1000)
    }

    /// Next shard in rotation.
    fn pick(&self) -> &Arc<AdmissionQueue> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        &self.shards[i]
    }

    /// Submit one request to the next shard in rotation and block for
    /// its result.
    pub fn submit(&self, request: SearchRequest) -> Result<SearchResponse, SearchError> {
        self.pick().submit(request)
    }

    /// Submit a pre-formed batch to ONE shard (rotation picks which) and
    /// block for all results. Keeping the batch on one shard preserves
    /// [`AdmissionQueue::enqueue_all`]'s guarantee that its requests
    /// occupy consecutive drain positions.
    pub fn submit_batch(
        &self,
        requests: Vec<SearchRequest>,
    ) -> Vec<Result<SearchResponse, SearchError>> {
        self.pick().submit_batch(requests)
    }

    /// Fan one ingest batch out to EVERY shard and block until all have
    /// applied it. The fan-out happens under one lock so concurrent
    /// ingests reach every shard in the same order — deterministic
    /// replicas stay replicas. All shards produce the same report (they
    /// apply identical writes to identical state); the first failure, if
    /// any, is returned instead.
    pub fn submit_ingest(&self, docs: Vec<Publication>) -> Result<IngestReport, SearchError> {
        let tickets: Vec<_> = {
            let _order = self.ingest_lock.lock().unwrap();
            self.shards.iter().map(|q| q.enqueue_ingest(docs.clone())).collect()
        };
        let mut report = None;
        for ticket in tickets {
            let r = ticket.wait()?;
            if report.is_none() {
                report = Some(r);
            }
        }
        Ok(report.expect("at least one shard"))
    }

    /// Aggregate admission counters across every shard
    /// (`largest_batch` takes the max, everything else sums).
    pub fn stats(&self) -> QueueStats {
        let mut total = QueueStats::default();
        for q in &self.shards {
            total.absorb(&q.stats());
        }
        total
    }

    /// Per-shard admission counters, in shard order.
    pub fn per_shard_stats(&self) -> Vec<QueueStats> {
        self.shards.iter().map(|q| q.stats()).collect()
    }

    /// Atomically consistent `/healthz` snapshot: every counter family
    /// is read under one [`Registry::freeze`], so no counter can move
    /// between reading the HTTP numbers and the queue numbers. Because
    /// executors bump `submitted` *after* the front bumps `requests`,
    /// a frozen snapshot always shows `http.requests >=` the sum of
    /// shard `submitted` — the drift the unfrozen reads allowed.
    ///
    /// Lock order matters: [`ShardRouter::index_health`] takes a queue
    /// mutex whose holder may be mid-bump on a registry cell, so it must
    /// run *before* the freeze, never under it.
    pub fn snapshot(&self) -> HealthSnapshot {
        let index = self.index_health();
        let frozen = self.obs.registry.freeze();
        let per_shard: Vec<QueueStats> = self.shards.iter().map(|q| q.stats()).collect();
        let mut queue = QueueStats::default();
        for s in &per_shard {
            queue.absorb(s);
        }
        let http = self.http.stats();
        drop(frozen);
        HealthSnapshot { queue, per_shard, http, index }
    }

    /// Index health as published by shard 0's executor. Every shard is a
    /// deterministic replica fed the same ingests in the same order, so
    /// their health converges; shard 0 is the canonical reporter.
    pub fn index_health(&self) -> Option<IndexHealth> {
        self.shards[0].index_health()
    }

    /// Whether the shards still accept submissions (false once
    /// [`ShardRouter::shutdown`] ran — shutdown closes every shard, so
    /// shard 0 is representative).
    pub fn is_open(&self) -> bool {
        self.shards[0].is_open()
    }

    /// Close every shard's queue: new submissions are rejected typed,
    /// pending rounds still drain.
    pub fn shutdown(&self) {
        for q in &self.shards {
            q.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::queue::QueueConfig;
    use std::time::Duration;

    fn shards(n: usize) -> Vec<Arc<AdmissionQueue>> {
        (0..n)
            .map(|_| {
                Arc::new(AdmissionQueue::new(QueueConfig {
                    max_batch: 4,
                    max_linger: Duration::ZERO,
                    ..QueueConfig::default()
                }))
            })
            .collect()
    }

    #[test]
    fn round_robin_rotates_across_shards() {
        let router = ShardRouter::new(shards(3));
        // enqueue (non-blocking) via the rotation: 6 submissions land 2
        // on each shard.
        for i in 0..6 {
            let _t = router.pick().enqueue(SearchRequest::new(format!("query {i}")));
        }
        for q in router.per_shard_stats() {
            assert_eq!(q.submitted, 2, "rotation must spread evenly");
        }
    }

    #[test]
    fn aggregate_stats_sum_and_take_max() {
        let router = ShardRouter::new(shards(2));
        let _a = router.shard(0).enqueue(SearchRequest::new("a"));
        let _b = router.shard(0).enqueue(SearchRequest::new("b"));
        let _c = router.shard(1).enqueue(SearchRequest::new("c"));
        router.shard(0).next_batch().expect("round of two");
        router.shard(1).next_batch().expect("round of one");
        let total = router.stats();
        assert_eq!(total.submitted, 3);
        assert_eq!(total.batches, 2);
        assert_eq!(total.largest_batch, 2, "max, not sum");
    }

    #[test]
    fn ingest_fans_out_to_every_shard() {
        use crate::corpus::Publication;
        let router = Arc::new(ShardRouter::new(shards(3)));
        let docs = vec![Publication {
            id: 1,
            title: "fanned out".into(),
            abstract_text: "every replica sees the write".into(),
            authors: "A".into(),
            venue: "T".into(),
            year: 2026,
        }];
        let r = Arc::clone(&router);
        let waiter = std::thread::spawn(move || r.submit_ingest(docs));
        // Every shard must receive the batch; settle each so the fan-out
        // waiter unblocks.
        for i in 0..3 {
            match router.shard(i).next_round() {
                Some(crate::serve::queue::Round::Ingest(b)) => {
                    assert_eq!(b.len(), 1);
                    b.complete(Ok(crate::coordinator::IngestReport {
                        accepted: 1,
                        epoch: 9,
                        ..Default::default()
                    }));
                }
                _ => panic!("expected ingest round on shard {i}"),
            }
        }
        let report = waiter.join().unwrap().expect("all shards settled");
        assert_eq!(report.accepted, 1);
        assert_eq!(report.epoch, 9);
        for q in router.per_shard_stats() {
            assert_eq!(q.ingest_batches, 1, "every shard must see the write");
        }
    }

    #[test]
    fn shutdown_closes_every_shard() {
        let router = ShardRouter::new(shards(2));
        assert!(router.is_open());
        router.shutdown();
        assert!(!router.is_open());
        for i in 0..2 {
            assert!(router.shard(i).submit(SearchRequest::new("late")).is_err());
        }
    }

    #[test]
    fn http_counters_track_connections_and_requests() {
        let c = HttpCounters::default();
        c.begin_connection();
        c.begin_connection();
        c.count_request(false);
        c.count_request(true);
        c.shed_connection();
        c.end_connection();
        let s = c.stats();
        assert_eq!(s.accepted, 2);
        assert_eq!(s.active, 1);
        assert_eq!(s.shed, 1);
        assert_eq!(s.requests, 2);
        assert_eq!(s.reused, 1);
        let j = s.to_json();
        assert_eq!(j.get("accepted").unwrap().as_i64(), Some(2));
        assert_eq!(j.get("reused").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn snapshot_freezes_http_and_queue_families_together() {
        let obs = ServeObs::default();
        let queues: Vec<Arc<AdmissionQueue>> = (0..2)
            .map(|i| {
                Arc::new(AdmissionQueue::with_registry(
                    QueueConfig {
                        max_batch: 4,
                        max_linger: Duration::ZERO,
                        ..QueueConfig::default()
                    },
                    &obs.registry,
                    Some(i),
                ))
            })
            .collect();
        let router = ShardRouter::with_obs(queues, obs);
        router.http().begin_connection();
        router.http().count_request(false);
        let _t = router.shard(0).enqueue(SearchRequest::new("a"));
        let snap = router.snapshot();
        assert_eq!(snap.http.accepted, 1);
        assert_eq!(snap.http.requests, 1);
        assert_eq!(snap.queue.submitted, 1);
        assert_eq!(snap.per_shard.len(), 2);
        assert_eq!(snap.per_shard[0].submitted, 1);
        assert!(snap.index.is_none(), "no executor has published health yet");
        // The same cells back the Prometheus exposition.
        let text = router.obs().registry.render_text();
        assert!(text.contains("gaps_http_requests_total 1"), "{text}");
        assert!(
            text.contains("gaps_queue_submitted_total{shard=\"0\"} 1"),
            "{text}"
        );
    }

    #[test]
    fn retry_after_takes_the_deepest_shard() {
        let router = ShardRouter::new(shards(2));
        // Empty queues: hint is the base linger (clamped to >= 1ms).
        let base = router.retry_after_ms();
        assert!(base >= 1);
        // Back up one shard past max_batch: its hint dominates.
        for i in 0..5 {
            let _t = router.shard(1).enqueue(SearchRequest::new(format!("q{i}")));
        }
        assert!(
            router.retry_after_ms() >= 2 * base,
            "deep shard must raise the hint: {} vs {base}",
            router.retry_after_ms()
        );
    }
}
