//! Hand-rolled HTTP/1.1 front-end over the sharded admission layer.
//!
//! The offline crate set has no hyper/axum, and the protocol surface the
//! serving layer needs is tiny, so this is a from-scratch implementation
//! on `std::net::TcpListener`: request-line + headers + `Content-Length`
//! body. Every body in and out is the *existing* `util::json` wire form
//! — the same encoding the Query Manager ships in JDFs — so an HTTP
//! client, the USI, and the grid's internal serialization all speak one
//! dialect.
//!
//! **Connection model (keep-alive + pipelining):** connections are
//! persistent by default (HTTP/1.1 semantics): a handler serves
//! requests off one socket back-to-back until the client sends
//! `Connection: close`, closes its end, or goes idle past the read
//! timeout (an idle gap between requests closes quietly — there is no
//! request to answer 408 to). Because requests are read sequentially
//! off one buffered reader, *pipelined* requests (several written
//! back-to-back before reading any response) are answered in order with
//! no extra machinery. Responses echo the connection's fate
//! (`Connection: keep-alive` or `Connection: close`); framing errors
//! (400/408/411/413) always close, since the stream position is no
//! longer trustworthy. Setting [`HttpConfig::keep_alive`] to false
//! restores the one-request-per-connection behaviour.
//!
//! **Bounded handler pool:** connections are served by a fixed pool of
//! [`HttpConfig::handlers`] resident workers (`util::pool`), not a
//! thread per connection. The acceptor gates on the live-connection
//! count: past the bound it *sheds* — writes a complete typed 503
//! `overloaded` response with a `Retry-After` hint on the acceptor
//! thread and closes, so an over-capacity client is never left hanging
//! on an unanswered socket. Shed counts are visible on `GET /healthz`.
//!
//! **Executor shards:** requests route through a [`ShardRouter`] —
//! round-robin over N deterministic `GapsSystem` replicas, each drained
//! by its own executor thread (see [`super::router`]).
//!
//! Routes:
//!
//! | Route                | Body in                       | Body out |
//! |----------------------|-------------------------------|----------|
//! | `POST /search`       | `SearchRequest` JSON          | `SearchResponse` JSON, or `SearchError` JSON with a mapped status |
//! | `POST /search_batch` | `{"requests": [...]}` (or a bare array) | `{"results": [{"ok": ...} \| {"error": ...}]}` |
//! | `POST /ingest`       | `{"docs": [...]}` (or a bare array of publication objects) | `IngestReport` JSON (`{"accepted", "buffered", "sealed", "merges", "epoch"}`) |
//! | `GET /healthz`       | —                             | `{"status": "ok", "queue": {...}, "shards": [...], "http": {...}, "index": {...}}` (one frozen registry snapshot: aggregate + per-shard admission counters, connection counters, index health) |
//! | `GET /metrics`       | —                             | Prometheus text exposition (`text/plain; version=0.0.4`) of every registered counter/gauge/histogram |
//! | `GET /debug/slow`    | —                             | `{"capacity": N, "entries": [...]}` — the slow-query ring, oldest first |
//!
//! Error statuses ([`status_for`]): `parse` → 400; `no-sources`,
//! `no-nodes`, `no-live-replica`, `unavailable` → 503; `overloaded` →
//! 503 with a `Retry-After` header; `deadline-exceeded` → 504;
//! everything else (server-side faults) → 500. Protocol-level failures
//! use 404/405/408/411/413/400 with a `{"kind", "message"}` body shaped
//! like `SearchError::to_json`.
//!
//! Sockets carry read/write timeouts ([`HttpConfig`]): a client that
//! stalls mid-request is answered 408 instead of pinning its handler,
//! and a peer that stops reading its response cannot wedge the writer.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::corpus::Publication;
use crate::search::{SearchError, SearchRequest};
use crate::util::json::Json;
use crate::util::pool::Pool;

use super::queue::QueueStats;
use super::router::{HttpCounters, ShardRouter};

/// Largest accepted request body (a request batch of thousands of typed
/// queries fits comfortably; anything bigger is a client error).
const MAX_BODY: usize = 1 << 20;

/// Largest accepted request head (request line + headers): a peer
/// streaming an endless newline-free request line runs into this cap, so
/// a handler's buffers stay bounded. The body has its own separate
/// [`MAX_BODY`] cap.
const MAX_HEAD: usize = 16 << 10;

// Acceptor-side shedding and admission-queue shedding both derive their
// `Retry-After` hint from queue depth via [`super::retry_after_hint`] —
// there is no longer a bare constant for either door.

/// Socket + connection-model knobs for the front-end (the `gaps serve`
/// CLI exposes them via the `serve.*` config section).
#[derive(Debug, Clone, Copy)]
pub struct HttpConfig {
    /// Per-socket read timeout: a client that stalls mid-request is
    /// answered 408 (an idle keep-alive connection between requests is
    /// closed quietly instead). Zero disables the timeout (blocking
    /// reads).
    pub read_timeout: Duration,
    /// Per-socket write timeout for the response path. Zero disables.
    pub write_timeout: Duration,
    /// Bounded handler pool size: at most this many connections are
    /// served concurrently; further connections are shed with a
    /// complete 503 + `Retry-After` response (clamped up to 1).
    pub handlers: usize,
    /// Persistent connections (HTTP/1.1 keep-alive + pipelined reads).
    /// False restores one-request-per-connection: every response
    /// carries `Connection: close`.
    pub keep_alive: bool,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            read_timeout: Duration::from_millis(10_000),
            write_timeout: Duration::from_millis(10_000),
            handlers: 32,
            keep_alive: true,
        }
    }
}

/// HTTP status for a typed search failure. Client-side query problems
/// are 400s; capacity/availability exhaustion (every replica of some
/// source down, no live nodes, draining, shedding) is 503; a blown
/// per-request deadline is the gateway-timeout 504; internal faults are
/// 500s.
pub fn status_for(e: &SearchError) -> u16 {
    match e {
        SearchError::Parse { .. } => 400,
        SearchError::NoSources
        | SearchError::NoNodes
        | SearchError::NoLiveReplica { .. }
        | SearchError::Unavailable { .. }
        | SearchError::Overloaded { .. } => 503,
        SearchError::DeadlineExceeded { .. } => 504,
        SearchError::SourceUnknown { .. }
        | SearchError::ExecutorFailure { .. }
        | SearchError::InvalidConfig { .. }
        | SearchError::Io { .. }
        | SearchError::Internal { .. } => 500,
    }
}

/// `Retry-After` hint (whole seconds, rounded up) for errors that carry
/// one — admission-queue shedding and acceptor-side connection
/// shedding.
fn retry_after_secs(e: &SearchError) -> Option<u64> {
    match e {
        SearchError::Overloaded { retry_after_ms } => Some((retry_after_ms + 999) / 1000),
        _ => None,
    }
}

/// A response payload: JSON on every API route, plain text on
/// `GET /metrics` (the Prometheus exposition format is line-oriented
/// text, not JSON). The variant picks the `Content-Type`.
enum Body {
    Json(Json),
    Text(String),
}

impl Body {
    fn content_type(&self) -> &'static str {
        match self {
            Body::Json(_) => "application/json",
            // The version parameter is the Prometheus text-format tag.
            Body::Text(_) => "text/plain; version=0.0.4",
        }
    }

    fn render(&self) -> String {
        match self {
            Body::Json(j) => j.to_string_compact(),
            Body::Text(t) => t.clone(),
        }
    }

    #[cfg(test)]
    fn as_json(&self) -> &Json {
        match self {
            Body::Json(j) => j,
            Body::Text(t) => panic!("expected a JSON body, got text: {t:?}"),
        }
    }

    #[cfg(test)]
    fn as_text(&self) -> &str {
        match self {
            Body::Text(t) => t,
            Body::Json(j) => panic!("expected a text body, got JSON: {j:?}"),
        }
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Error",
    }
}

/// `{"kind": ..., "message": ...}` — protocol errors share the shape of
/// `SearchError::to_json` so clients parse one error envelope.
fn error_body(kind: &str, message: &str) -> Json {
    Json::obj(vec![("kind", Json::str(kind)), ("message", Json::str(message))])
}

/// A parsed request: method + path + raw body + the client's connection
/// preference.
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
    /// The client sent `Connection: close` (HTTP/1.1 defaults to
    /// keep-alive, so anything else leaves the connection open).
    close: bool,
}

/// Status for an I/O failure while reading the request: a socket read
/// timeout (a stalled or too-slow client; `WouldBlock` on Unix,
/// `TimedOut` on Windows) is 408, anything else is a client framing
/// error.
fn read_status(e: &io::Error) -> u16 {
    match e.kind() {
        io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => 408,
        _ => 400,
    }
}

/// Read one HTTP/1.1 request. Errors are `(status, message)` pairs ready
/// to be rendered as an error response (after which the connection must
/// close — the stream position is unknown).
fn read_request(reader: &mut impl BufRead) -> Result<HttpRequest, (u16, String)> {
    // The head reads through a MAX_HEAD cap of its own: a head that
    // never terminates runs into the limit, `read_line` returns the
    // bounded partial line, and parsing rejects it — memory stays
    // bounded without the head eating into the body's budget.
    let mut head = reader.take(MAX_HEAD as u64);
    let mut line = String::new();
    // Tolerate blank line(s) before the request line (RFC 9112 §2.2 —
    // e.g. a pipelining client that terminated the previous body with a
    // stray CRLF). The head cap still bounds the skipping.
    loop {
        line.clear();
        let n = head
            .read_line(&mut line)
            .map_err(|e| (read_status(&e), format!("reading request line: {e}")))?;
        if n == 0 || !line.trim_end().is_empty() {
            break;
        }
    }
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if v.starts_with("HTTP/1.") => {
            (m.to_string(), p.to_string())
        }
        _ => return Err((400, format!("malformed request line {:?}", line.trim_end()))),
    };

    let mut content_length: Option<usize> = None;
    let mut close = false;
    loop {
        let mut header = String::new();
        head.read_line(&mut header)
            .map_err(|e| (read_status(&e), format!("reading headers: {e}")))?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = Some(
                    value
                        .trim()
                        .parse()
                        .map_err(|_| (400u16, format!("bad content-length {value:?}")))?,
                );
            } else if name.eq_ignore_ascii_case("connection") {
                close = value.trim().eq_ignore_ascii_case("close");
            }
        }
    }

    // Body bytes read from the un-capped inner reader again (the
    // `read_exact` buffer of `n <= MAX_BODY` bytes is its own bound) so
    // a header-heavy request cannot starve a legitimate full-size body.
    let reader = head.into_inner();
    let body = match content_length {
        // Only POST carries a body here; other methods (incl. the ones
        // the router answers with 405) are read body-less so routing,
        // not framing, decides their status.
        None if method == "POST" => {
            return Err((411, "POST requires a Content-Length header".into()))
        }
        None => Vec::new(),
        Some(n) if n > MAX_BODY => {
            return Err((413, format!("body of {n} bytes exceeds the {MAX_BODY} cap")))
        }
        Some(_) if method == "GET" || method == "HEAD" => Vec::new(),
        Some(n) => {
            let mut body = vec![0u8; n];
            reader
                .read_exact(&mut body)
                .map_err(|e| (read_status(&e), format!("reading {n}-byte body: {e}")))?;
            body
        }
    };
    Ok(HttpRequest { method, path, body, close })
}

fn parse_body_json(body: &[u8]) -> Result<Json, (u16, String)> {
    let text = std::str::from_utf8(body).map_err(|_| (400u16, "body is not UTF-8".to_string()))?;
    Json::parse(text).map_err(|e| (400, format!("body is not valid JSON: {e}")))
}

/// Requests of `POST /search_batch`: `{"requests": [...]}` or a bare
/// array of request objects.
fn parse_batch(v: &Json) -> Result<Vec<SearchRequest>, (u16, String)> {
    let items = v
        .get("requests")
        .and_then(Json::as_arr)
        .or_else(|| v.as_arr())
        .ok_or_else(|| (400u16, "expected {\"requests\": [...]} or a JSON array".to_string()))?;
    items
        .iter()
        .enumerate()
        .map(|(i, item)| {
            SearchRequest::from_json(item)
                .ok_or_else(|| (400, format!("requests[{i}] is not a search request")))
        })
        .collect()
}

/// Requests of `POST /ingest`: `{"docs": [...]}` or a bare array of
/// publication objects.
fn parse_ingest(v: &Json) -> Result<Vec<Publication>, (u16, String)> {
    let items = v
        .get("docs")
        .and_then(Json::as_arr)
        .or_else(|| v.as_arr())
        .ok_or_else(|| (400u16, "expected {\"docs\": [...]} or a JSON array".to_string()))?;
    items
        .iter()
        .enumerate()
        .map(|(i, item)| {
            Publication::from_json(item)
                .ok_or_else(|| (400, format!("docs[{i}] is not a publication object")))
        })
        .collect()
}

/// Route one request to a `(status, body, Retry-After)` triple. Pure
/// apart from the shard-router interaction, so the protocol is
/// unit-testable.
fn respond(req: &HttpRequest, router: &ShardRouter) -> (u16, Body, Option<u64>) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            // One frozen registry snapshot: the queue, per-shard, and
            // http objects are mutually consistent (no counter moves
            // between reading one family and the next).
            let snap = router.snapshot();
            let mut fields = vec![
                ("status", Json::str("ok")),
                ("queue", snap.queue.to_json()),
                (
                    "shards",
                    Json::Arr(snap.per_shard.iter().map(QueueStats::to_json).collect()),
                ),
                ("http", snap.http.to_json()),
            ];
            // The index object appears once an executor has published
            // (always, on a served system; absent on a bare queue).
            if let Some(health) = snap.index {
                fields.push(("index", health.to_json()));
            }
            (200, Body::Json(Json::obj(fields)), None)
        }
        ("GET", "/metrics") => {
            (200, Body::Text(router.obs().registry.render_text()), None)
        }
        ("GET", "/debug/slow") => (200, Body::Json(router.obs().slow.to_json()), None),
        ("POST", "/search") => {
            let parsed = parse_body_json(&req.body).and_then(|v| {
                SearchRequest::from_json(&v)
                    .ok_or_else(|| (400, "body is not a search request".to_string()))
            });
            match parsed {
                Ok(request) => match router.submit(request) {
                    Ok(resp) => (200, Body::Json(resp.to_json()), None),
                    Err(e) => (status_for(&e), Body::Json(e.to_json()), retry_after_secs(&e)),
                },
                Err((status, msg)) => (status, Body::Json(error_body("bad-request", &msg)), None),
            }
        }
        ("POST", "/search_batch") => {
            match parse_body_json(&req.body).and_then(|v| parse_batch(&v)) {
                Ok(requests) => {
                    let results = router
                        .submit_batch(requests)
                        .into_iter()
                        .map(|r| match r {
                            Ok(resp) => Json::obj(vec![("ok", resp.to_json())]),
                            Err(e) => Json::obj(vec![("error", e.to_json())]),
                        })
                        .collect();
                    (200, Body::Json(Json::obj(vec![("results", Json::Arr(results))])), None)
                }
                Err((status, msg)) => (status, Body::Json(error_body("bad-request", &msg)), None),
            }
        }
        ("POST", "/ingest") => {
            match parse_body_json(&req.body).and_then(|v| parse_ingest(&v)) {
                Ok(docs) => match router.submit_ingest(docs) {
                    Ok(report) => (200, Body::Json(report.to_json()), None),
                    Err(e) => (status_for(&e), Body::Json(e.to_json()), retry_after_secs(&e)),
                },
                Err((status, msg)) => (status, Body::Json(error_body("bad-request", &msg)), None),
            }
        }
        (_, "/healthz" | "/metrics" | "/debug/slow" | "/search" | "/search_batch" | "/ingest") => (
            405,
            Body::Json(error_body(
                "method-not-allowed",
                &format!("{} not allowed here", req.method),
            )),
            None,
        ),
        (_, path) => {
            (404, Body::Json(error_body("not-found", &format!("no route {path}"))), None)
        }
    }
}

fn write_response(
    stream: &mut impl Write,
    status: u16,
    body: &Body,
    retry_after: Option<u64>,
    close: bool,
) -> io::Result<()> {
    let content_type = body.content_type();
    let body = body.render();
    let retry = retry_after.map(|s| format!("Retry-After: {s}\r\n")).unwrap_or_default();
    let connection = if close { "close" } else { "keep-alive" };
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n{retry}Connection: {connection}\r\n\r\n",
        reason(status),
        body.len(),
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Serve one connection until it closes: requests are read sequentially
/// off the buffered reader (which is what makes pipelining work), each
/// answered in order. The connection ends on `Connection: close`, a
/// framing error, clean EOF, an idle timeout between requests, or — the
/// drain path — once a shut-down admission layer has answered
/// everything the client already pipelined.
fn handle_connection(stream: TcpStream, router: &ShardRouter, cfg: HttpConfig) -> io::Result<()> {
    // `set_read_timeout(Some(ZERO))` is an error on std sockets — zero
    // means "no timeout" here, so gate instead of passing it through.
    if cfg.read_timeout > Duration::ZERO {
        stream.set_read_timeout(Some(cfg.read_timeout))?;
    }
    if cfg.write_timeout > Duration::ZERO {
        stream.set_write_timeout(Some(cfg.write_timeout))?;
    }
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut served = 0u64;
    loop {
        // Wait for the next request's first byte. Clean EOF — or an
        // idle timeout with no request bytes in flight — closes the
        // connection quietly: between requests there is nothing to
        // answer 408 to.
        let has_bytes = match reader.fill_buf() {
            Ok(buf) => !buf.is_empty(),
            Err(_) => false,
        };
        if !has_bytes {
            return Ok(());
        }
        let (status, body, retry_after, mut close) = match read_request(&mut reader) {
            Ok(req) => {
                router.http().count_request(served > 0);
                served += 1;
                let close = !cfg.keep_alive || req.close;
                let (status, body, retry) = respond(&req, router);
                (status, body, retry, close)
            }
            Err((status, msg)) => {
                // Framing failed: the stream position is unknown, so
                // the connection cannot be reused.
                let kind = if status == 408 { "timeout" } else { "bad-request" };
                (status, Body::Json(error_body(kind, &msg)), None, true)
            }
        };
        // Drain-settle on shutdown: requests the client already
        // pipelined keep being answered (each one typed by the queue's
        // own 503), and once the read buffer holds no more of them the
        // connection closes instead of idling against a draining
        // server — no abrupt resets mid-pipeline.
        if !close && !router.is_open() && reader.buffer().is_empty() {
            close = true;
        }
        write_response(&mut writer, status, &body, retry_after, close)?;
        if close {
            return Ok(());
        }
    }
}

/// Acceptor-side shedding: every handler is busy, so this connection is
/// answered with a complete typed 503 + `Retry-After` and closed — on
/// the acceptor thread, without occupying a handler. A shed client is
/// never left hanging on a silent socket. The retry hint is the
/// router's depth-derived one ([`ShardRouter::retry_after_ms`]) — the
/// same formula the admission queue's own shed path uses, so both
/// doors advise consistently.
fn shed_connection(mut stream: TcpStream, cfg: HttpConfig, retry_after_ms: u64) -> io::Result<()> {
    if cfg.write_timeout > Duration::ZERO {
        stream.set_write_timeout(Some(cfg.write_timeout))?;
    }
    let e = SearchError::Overloaded { retry_after_ms };
    write_response(&mut stream, 503, &Body::Json(e.to_json()), retry_after_secs(&e), true)
}

/// The HTTP listener: accepts connections onto a bounded pool of
/// resident handler workers; connections beyond the bound are shed with
/// a typed 503 (handlers block on the admission layer while their round
/// coalesces — cheap OS threads are exactly right for that, but a
/// *bounded* number of them).
pub struct HttpServer {
    listener: TcpListener,
    router: Arc<ShardRouter>,
    cfg: HttpConfig,
    stop: Arc<AtomicBool>,
}

/// Handle for stopping a running [`HttpServer::serve`] loop from another
/// thread.
#[derive(Clone)]
pub struct ShutdownHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ShutdownHandle {
    /// Stop the accept loop (idempotent). Wakes the blocking `accept`
    /// with a throwaway local connection.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
    }
}

impl HttpServer {
    /// Bind the front-end with default socket timeouts. `addr` may use
    /// port 0 for an ephemeral port (see [`HttpServer::local_addr`]).
    pub fn bind(addr: &str, router: Arc<ShardRouter>) -> io::Result<HttpServer> {
        Self::bind_with(addr, router, HttpConfig::default())
    }

    /// Bind the front-end with explicit socket + connection knobs.
    pub fn bind_with(
        addr: &str,
        router: Arc<ShardRouter>,
        cfg: HttpConfig,
    ) -> io::Result<HttpServer> {
        Ok(HttpServer {
            listener: TcpListener::bind(addr)?,
            router,
            cfg,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (the real port when bound with port 0).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// A handle that stops [`HttpServer::serve`] from another thread.
    pub fn shutdown_handle(&self) -> io::Result<ShutdownHandle> {
        Ok(ShutdownHandle { stop: Arc::clone(&self.stop), addr: self.local_addr()? })
    }

    /// Accept loop: blocks until [`ShutdownHandle::stop`] is called.
    /// Connections are served by a bounded resident pool
    /// ([`HttpConfig::handlers`]); connections beyond the pool's
    /// capacity are shed with a complete 503 + `Retry-After`. Accept
    /// errors are skipped after a short backoff (a persistent failure
    /// such as fd exhaustion must not busy-spin the acceptor at 100%
    /// CPU while the very handlers holding the fds try to finish).
    /// Returning drains the pool: in-flight connections finish before
    /// `serve` comes back.
    pub fn serve(self) -> io::Result<()> {
        let pool = Pool::new(self.cfg.handlers.max(1));
        let handlers = pool.size() as u64;
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(stream) => stream,
                Err(_) => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    continue;
                }
            };
            if self.router.http().active() >= handlers {
                // Every handler is occupied (keep-alive connections
                // hold theirs until they close): shed at the door.
                self.router.http().shed_connection();
                let _ = shed_connection(stream, self.cfg, self.router.retry_after_ms());
                continue;
            }
            self.router.http().begin_connection();
            let router = Arc::clone(&self.router);
            let cfg = self.cfg;
            pool.submit(move || {
                // The active count must drop however the handler exits.
                struct Active<'a>(&'a HttpCounters);
                impl Drop for Active<'_> {
                    fn drop(&mut self) {
                        self.0.end_connection();
                    }
                }
                let _active = Active(router.http());
                let _ = handle_connection(stream, &router, cfg);
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::queue::{AdmissionQueue, QueueConfig};
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<HttpRequest, (u16, String)> {
        read_request(&mut Cursor::new(raw.as_bytes()))
    }

    fn test_router() -> ShardRouter {
        ShardRouter::single(Arc::new(AdmissionQueue::new(QueueConfig::default())))
    }

    #[test]
    fn parses_get_and_post() {
        let get = parse("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(get.method, "GET");
        assert_eq!(get.path, "/healthz");
        assert!(get.body.is_empty());
        assert!(!get.close, "HTTP/1.1 defaults to keep-alive");

        let post = parse(
            "POST /search HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: 17\r\n\r\n{\"query\": \"grid\"}",
        )
        .unwrap();
        assert_eq!(post.method, "POST");
        assert_eq!(std::str::from_utf8(&post.body).unwrap(), "{\"query\": \"grid\"}");
    }

    #[test]
    fn connection_close_header_is_parsed_case_insensitively() {
        let req = parse("GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(req.close);
        let req = parse("GET /healthz HTTP/1.1\r\nconnection: CLOSE\r\n\r\n").unwrap();
        assert!(req.close);
        let req = parse("GET /healthz HTTP/1.1\r\nConnection: keep-alive\r\n\r\n").unwrap();
        assert!(!req.close);
    }

    #[test]
    fn blank_lines_before_the_request_line_are_skipped() {
        // RFC 9112 §2.2: a server SHOULD ignore at least one empty line
        // received before the request line.
        let req = parse("\r\n\r\nGET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.path, "/healthz");
    }

    #[test]
    fn content_length_is_case_insensitive() {
        let post =
            parse("POST /search HTTP/1.1\r\ncontent-length: 2\r\n\r\nok").unwrap();
        assert_eq!(post.body, b"ok");
    }

    #[test]
    fn post_without_length_is_411() {
        let err = parse("POST /search HTTP/1.1\r\n\r\n{}").unwrap_err();
        assert_eq!(err.0, 411);
    }

    #[test]
    fn oversized_body_is_413() {
        let err = parse(&format!(
            "POST /search HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY + 1
        ))
        .unwrap_err();
        assert_eq!(err.0, 413);
    }

    #[test]
    fn garbage_request_line_is_400() {
        assert_eq!(parse("nonsense\r\n\r\n").unwrap_err().0, 400);
        assert_eq!(parse("GET /x SPDY/9\r\n\r\n").unwrap_err().0, 400);
        assert_eq!(
            parse("POST /x HTTP/1.1\r\nContent-Length: soon\r\n\r\n").unwrap_err().0,
            400
        );
    }

    #[test]
    fn endless_request_line_is_bounded_and_rejected() {
        // A newline-free head longer than the total read cap must be
        // cut off at the cap and rejected, not buffered without bound.
        let raw = "A".repeat(MAX_HEAD + MAX_BODY + 4096);
        let err = parse(&raw).unwrap_err();
        assert_eq!(err.0, 400);
    }

    #[test]
    fn truncated_body_is_400() {
        let err =
            parse("POST /search HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort").unwrap_err();
        assert_eq!(err.0, 400);
    }

    #[test]
    fn status_mapping_is_total_and_documented() {
        // The README table and this mapping must agree.
        assert_eq!(status_for(&SearchError::parse("x")), 400);
        assert_eq!(status_for(&SearchError::NoSources), 503);
        assert_eq!(status_for(&SearchError::NoNodes), 503);
        assert_eq!(status_for(&SearchError::NoLiveReplica { source: 1 }), 503);
        assert_eq!(status_for(&SearchError::unavailable("draining")), 503);
        assert_eq!(status_for(&SearchError::Overloaded { retry_after_ms: 25 }), 503);
        assert_eq!(status_for(&SearchError::DeadlineExceeded { deadline_ms: 5 }), 504);
        assert_eq!(status_for(&SearchError::SourceUnknown { source: 1 }), 500);
        assert_eq!(status_for(&SearchError::executor("x")), 500);
        assert_eq!(status_for(&SearchError::config("x")), 500);
        assert_eq!(status_for(&SearchError::Io { message: "x".into() }), 500);
        assert_eq!(status_for(&SearchError::internal("x")), 500);
    }

    #[test]
    fn healthz_and_unknown_routes_without_executor() {
        // Routes that never touch an executor are fully testable here.
        let router = test_router();
        let get = |method: &str, path: &str| HttpRequest {
            method: method.into(),
            path: path.into(),
            body: Vec::new(),
            close: false,
        };
        let (status, body, retry) = respond(&get("GET", "/healthz"), &router);
        assert_eq!(status, 200);
        assert_eq!(retry, None);
        let body = body.as_json();
        assert_eq!(body.get("status").unwrap().as_str(), Some("ok"));
        assert!(body.get("queue").unwrap().get("submitted").is_some());
        let shards = body.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 1, "one per-shard stats object per shard");
        assert!(shards[0].get("submitted").is_some());
        let http = body.get("http").expect("connection counters");
        assert_eq!(http.get("shed").unwrap().as_i64(), Some(0));

        assert_eq!(respond(&get("GET", "/nope"), &router).0, 404);
        assert_eq!(respond(&get("DELETE", "/search"), &router).0, 405);
        assert_eq!(respond(&get("POST", "/healthz"), &router).0, 405);
        assert_eq!(respond(&get("GET", "/ingest"), &router).0, 405);
        assert_eq!(respond(&get("POST", "/metrics"), &router).0, 405);
        assert_eq!(respond(&get("POST", "/debug/slow"), &router).0, 405);
    }

    #[test]
    fn metrics_route_renders_prometheus_text() {
        let router = test_router();
        router.http().count_request(false);
        let req = HttpRequest {
            method: "GET".into(),
            path: "/metrics".into(),
            body: Vec::new(),
            close: false,
        };
        let (status, body, retry) = respond(&req, &router);
        assert_eq!(status, 200);
        assert_eq!(retry, None);
        assert_eq!(body.content_type(), "text/plain; version=0.0.4");
        let text = body.as_text();
        assert!(text.contains("# TYPE gaps_http_requests_total counter"), "{text}");
        assert!(text.contains("gaps_http_requests_total 1"), "{text}");
    }

    #[test]
    fn debug_slow_route_dumps_the_ring() {
        use crate::obs::SlowEntry;
        let router = test_router();
        router.obs().slow.record(SlowEntry {
            fingerprint: 7,
            query: "slow one".into(),
            shard: 0,
            epoch: 0,
            total_s: 1.25,
            degraded: false,
            error: None,
            counters: None,
            stages: None,
        });
        let req = HttpRequest {
            method: "GET".into(),
            path: "/debug/slow".into(),
            body: Vec::new(),
            close: false,
        };
        let (status, body, _) = respond(&req, &router);
        assert_eq!(status, 200);
        let body = body.as_json();
        let entries = body.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("query").unwrap().as_str(), Some("slow one"));
    }

    #[test]
    fn healthz_reports_index_health_once_published() {
        use crate::coordinator::IndexHealth;
        let router = test_router();
        let get = HttpRequest {
            method: "GET".into(),
            path: "/healthz".into(),
            body: Vec::new(),
            close: false,
        };

        // Before an executor publishes: no `index` object.
        let (_, body, _) = respond(&get, &router);
        assert!(body.as_json().get("index").is_none());

        router.shard(0).publish_index_health(IndexHealth {
            epoch: 7,
            searchable_docs: 640,
            buffered_docs: 2,
            segments: vec![(1, 3)],
            seals: 6,
            merges: 1,
        });
        let (status, body, _) = respond(&get, &router);
        assert_eq!(status, 200);
        let body = body.as_json();
        let index = body.get("index").expect("index object after publication");
        assert_eq!(index.get("epoch").unwrap().as_i64(), Some(7));
        assert_eq!(index.get("searchable_docs").unwrap().as_i64(), Some(640));
        assert_eq!(
            IndexHealth::from_json(index).expect("round-trips").segments,
            vec![(1, 3)]
        );
    }

    #[test]
    fn malformed_ingest_bodies_are_400_without_executor() {
        let router = test_router();
        let post = |body: &str| HttpRequest {
            method: "POST".into(),
            path: "/ingest".into(),
            body: body.as_bytes().to_vec(),
            close: false,
        };
        assert_eq!(respond(&post("not json"), &router).0, 400);
        assert_eq!(respond(&post("{\"no_docs\": 1}"), &router).0, 400);
        assert_eq!(respond(&post("{\"docs\": [7]}"), &router).0, 400);
        assert_eq!(respond(&post("{\"docs\": [{\"title\": \"only\"}]}"), &router).0, 400);
        // Rejected bodies never reach the ingestion lane.
        assert_eq!(router.stats().ingest_batches, 0);
    }

    #[test]
    fn ingest_parse_accepts_both_shapes() {
        let doc = "{\"id\": 1, \"title\": \"t\", \"abstract\": \"a\", \
                   \"authors\": \"x\", \"venue\": \"v\", \"year\": 2026}";
        let wrapped = Json::parse(&format!("{{\"docs\": [{doc}]}}")).unwrap();
        assert_eq!(parse_ingest(&wrapped).unwrap().len(), 1);
        let bare = Json::parse(&format!("[{doc}, {doc}]")).unwrap();
        assert_eq!(parse_ingest(&bare).unwrap().len(), 2);
    }

    #[test]
    fn malformed_search_bodies_are_400_without_executor() {
        let router = test_router();
        let post = |path: &str, body: &str| HttpRequest {
            method: "POST".into(),
            path: path.into(),
            body: body.as_bytes().to_vec(),
            close: false,
        };
        assert_eq!(respond(&post("/search", "not json"), &router).0, 400);
        assert_eq!(respond(&post("/search", "{\"no_query\": 1}"), &router).0, 400);
        assert_eq!(respond(&post("/search_batch", "{\"requests\": [7]}"), &router).0, 400);
        assert_eq!(respond(&post("/search_batch", "17"), &router).0, 400);
    }

    #[test]
    fn batch_parse_accepts_both_shapes() {
        let wrapped =
            Json::parse("{\"requests\": [{\"query\": \"a\"}, {\"query\": \"b\"}]}").unwrap();
        assert_eq!(parse_batch(&wrapped).unwrap().len(), 2);
        let bare = Json::parse("[{\"query\": \"a\"}]").unwrap();
        assert_eq!(parse_batch(&bare).unwrap().len(), 1);
    }

    #[test]
    fn response_writer_emits_valid_http() {
        let mut out = Vec::new();
        write_response(
            &mut out,
            200,
            &Body::Json(Json::obj(vec![("a", Json::from(1i64))])),
            None,
            false,
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 7\r\n"), "{text}");
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
        assert!(!text.contains("Retry-After"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"a\":1}"), "{text}");
    }

    #[test]
    fn response_writer_echoes_the_close_decision() {
        let mut out = Vec::new();
        write_response(&mut out, 200, &Body::Json(Json::obj(vec![])), None, true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(!text.contains("keep-alive"), "{text}");
    }

    #[test]
    fn shed_responses_carry_retry_after() {
        // The header value rounds the millisecond hint up to whole
        // seconds, so a 1.5s linger advises a 2s backoff.
        let e = SearchError::Overloaded { retry_after_ms: 1500 };
        assert_eq!(retry_after_secs(&e), Some(2));
        assert_eq!(retry_after_secs(&SearchError::NoNodes), None);

        let mut out = Vec::new();
        write_response(&mut out, 503, &Body::Json(e.to_json()), retry_after_secs(&e), true)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("Retry-After: 2\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
    }

    #[test]
    fn read_timeouts_map_to_408() {
        let timed = io::Error::new(io::ErrorKind::TimedOut, "slow client");
        let blocked = io::Error::new(io::ErrorKind::WouldBlock, "slow client");
        let broken = io::Error::new(io::ErrorKind::UnexpectedEof, "truncated");
        assert_eq!(read_status(&timed), 408);
        assert_eq!(read_status(&blocked), 408);
        assert_eq!(read_status(&broken), 400);
    }
}
