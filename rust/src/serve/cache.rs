//! Sharded top-k result cache, keyed on the normalized-AST fingerprint
//! plus the index epoch.
//!
//! The serving executor probes this cache *after* compiling a request
//! (the compile itself goes through [`GapsSystem::compile_request`]'s
//! plan cache) and *before* dispatching a grid round: a hit answers the
//! submitter with a stored [`SearchResponse`] clone and never touches
//! the fabric. Because the fingerprint is computed over the
//! canonicalized AST (commutative operands sorted, duplicates deduped —
//! see [`crate::search::fingerprint`]), logically identical requests
//! like `b AND a` and `a AND b` share one entry.
//!
//! **Freshness:** every entry records the index epoch it was computed
//! under, and a probe only hits when the entry's epoch equals the
//! current one — a response computed before a segment seal or merge can
//! never be served afterwards. The executor additionally drops the
//! whole cache ([`ResultCache::invalidate_all`]) the moment it observes
//! an epoch bump, so stale entries do not linger as dead weight.
//!
//! **Collisions:** two distinct queries may collide on the 64-bit
//! fingerprint. Each entry therefore stores the canonical AST and the
//! result-affecting knobs it was computed for, and a probe verifies
//! them — a collision degrades to a miss, never to a wrong answer.
//!
//! **What is never cached:** degraded responses (they rank only the
//! reachable corpus) and errors. Placement-only knobs (`replicas`,
//! `deadline_ms`) are deliberately *outside* both the fingerprint and
//! the verification material: results are placement-invariant, so
//! requests differing only in placement share entries.
//!
//! **Sharded serving:** each executor shard owns a private instance —
//! there is no cross-shard cache coherence protocol, and none is
//! needed. The epoch in every key *is* the coherence mechanism: the
//! router fans each ingest batch out to every shard in the same order,
//! so replica epochs move in lockstep and a cached entry can only be
//! served by the shard that computed it, under the epoch it was
//! computed for. Shards answering bit-identically (they are
//! deterministic replicas) makes per-shard hit/miss divergence a
//! throughput detail, not a correctness one.

use std::collections::{HashMap, VecDeque};

use crate::config::CacheConfig;
use crate::coordinator::SearchResponse;
use crate::search::{CompiledRequest, QueryNode};

/// Deterministic result-cache counters (folded into
/// [`super::QueueStats`] and exposed via `GET /healthz`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounters {
    /// Probes answered from the cache (same fingerprint, same epoch,
    /// verification material matched).
    pub hits: u64,
    /// Probes that found nothing servable (absent, stale epoch, or a
    /// fingerprint collision caught by verification).
    pub misses: u64,
    /// Entries dropped to make room (per-shard FIFO eviction).
    pub evicted: u64,
    /// Entries dropped wholesale by an epoch bump
    /// ([`ResultCache::invalidate_all`]).
    pub invalidated: u64,
}

/// One cached response plus the material to verify a probe against.
struct Entry {
    /// Index epoch the response was computed under: a probe under any
    /// other epoch misses.
    epoch: u64,
    /// Canonical AST + result-affecting knobs — compared on probe so a
    /// 64-bit fingerprint collision degrades to a miss.
    ast: QueryNode,
    top_k: usize,
    allow_partial: bool,
    explain: bool,
    response: SearchResponse,
}

impl Entry {
    fn matches(&self, compiled: &CompiledRequest, epoch: u64) -> bool {
        self.epoch == epoch
            && self.top_k == compiled.top_k
            && self.allow_partial == compiled.allow_partial
            && self.explain == compiled.explain
            && self.ast == compiled.query.ast
    }
}

/// One shard: FIFO-evicting fingerprint map (insertion order is the
/// eviction order, so behaviour is deterministic for a fixed request
/// sequence).
struct Shard {
    capacity: usize,
    map: HashMap<u64, Entry>,
    order: VecDeque<u64>,
}

/// The sharded result cache. Owned by the serving executor thread (one
/// writer), so shards reduce probe cost on large capacities rather than
/// lock contention — but they also keep the layout ready for a
/// concurrent front should the executor ever be replicated.
pub struct ResultCache {
    /// `false` when `cache.enabled` is off or `cache.result_capacity`
    /// is 0: every operation is a silent no-op (not even counted).
    enabled: bool,
    shards: Vec<Shard>,
    counters: CacheCounters,
}

impl ResultCache {
    /// Build from the `cache.*` config section. `result_capacity` is
    /// split evenly across `result_shards` (rounded up, each shard
    /// holds at least one entry when the cache is enabled).
    pub fn new(cfg: &CacheConfig) -> ResultCache {
        let n = cfg.result_shards.max(1);
        let enabled = cfg.enabled && cfg.result_capacity > 0;
        let per_shard = if enabled { ((cfg.result_capacity + n - 1) / n).max(1) } else { 0 };
        ResultCache {
            enabled,
            shards: (0..n)
                .map(|_| Shard {
                    capacity: per_shard,
                    map: HashMap::new(),
                    order: VecDeque::new(),
                })
                .collect(),
            counters: CacheCounters::default(),
        }
    }

    fn shard_index(&self, fingerprint: u64) -> usize {
        (fingerprint as usize) % self.shards.len()
    }

    /// Probe for a response to `compiled` under `epoch`. A hit returns
    /// a clone of the stored response — bit-identical to what cold
    /// execution produced when it was inserted.
    pub fn get(&mut self, compiled: &CompiledRequest, epoch: u64) -> Option<SearchResponse> {
        if !self.enabled {
            return None;
        }
        let idx = self.shard_index(compiled.fingerprint);
        match self.shards[idx].map.get(&compiled.fingerprint) {
            Some(entry) if entry.matches(compiled, epoch) => {
                self.counters.hits += 1;
                Some(entry.response.clone())
            }
            _ => {
                self.counters.misses += 1;
                None
            }
        }
    }

    /// Store `response` for `compiled` under `epoch`, evicting the
    /// shard's oldest entry if it is full. Callers must not insert
    /// degraded responses (the executor filters them).
    pub fn insert(&mut self, compiled: &CompiledRequest, epoch: u64, response: SearchResponse) {
        if !self.enabled {
            return;
        }
        let idx = self.shard_index(compiled.fingerprint);
        let shard = &mut self.shards[idx];
        if shard.map.len() >= shard.capacity && !shard.map.contains_key(&compiled.fingerprint) {
            if let Some(oldest) = shard.order.pop_front() {
                shard.map.remove(&oldest);
                self.counters.evicted += 1;
            }
        }
        let entry = Entry {
            epoch,
            ast: compiled.query.ast.clone(),
            top_k: compiled.top_k,
            allow_partial: compiled.allow_partial,
            explain: compiled.explain,
            response,
        };
        if shard.map.insert(compiled.fingerprint, entry).is_none() {
            shard.order.push_back(compiled.fingerprint);
        }
    }

    /// Drop every entry (the epoch-bump invalidation hook): after a
    /// segment seal or merge the whole population is stale at once,
    /// since every key embeds the now-old epoch.
    pub fn invalidate_all(&mut self) {
        for shard in &mut self.shards {
            self.counters.invalidated += shard.map.len() as u64;
            shard.map.clear();
            shard.order.clear();
        }
    }

    /// Counter snapshot (published into [`super::QueueStats`]).
    pub fn counters(&self) -> CacheCounters {
        self.counters
    }

    /// Live entries across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.map.len()).sum()
    }

    /// Whether the cache currently holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::SearchRequest;
    use crate::util::clock::TaskTimeline;

    fn cache_cfg(capacity: usize, shards: usize) -> CacheConfig {
        CacheConfig {
            enabled: true,
            plan_capacity: 0,
            result_capacity: capacity,
            result_shards: shards,
        }
    }

    fn compiled(raw: &str) -> CompiledRequest {
        SearchRequest::new(raw).compile(512, 10).expect("compiles")
    }

    fn response(query: &str, docs_scanned: u64) -> SearchResponse {
        SearchResponse {
            query: query.to_string(),
            hits: Vec::new(),
            timeline: TaskTimeline::default(),
            jobs: 1,
            candidates: 0,
            docs_scanned,
            degraded: false,
            missing_sources: Vec::new(),
            explain: None,
            trace: None,
        }
    }

    #[test]
    fn hit_requires_the_same_epoch() {
        let mut cache = ResultCache::new(&cache_cfg(8, 2));
        let c = compiled("grid computing");
        cache.insert(&c, 3, response("grid computing", 100));
        assert!(cache.get(&c, 3).is_some(), "same epoch must hit");
        assert!(cache.get(&c, 4).is_none(), "a bumped epoch must never serve old results");
        let n = cache.counters();
        assert_eq!((n.hits, n.misses), (1, 1));
    }

    #[test]
    fn reordered_commutative_queries_share_one_entry() {
        let mut cache = ResultCache::new(&cache_cfg(8, 2));
        let ab = compiled("storage AND replication");
        let ba = compiled("replication AND storage");
        assert_eq!(ab.fingerprint, ba.fingerprint);
        cache.insert(&ab, 0, response("storage AND replication", 7));
        let served = cache.get(&ba, 0).expect("reordered form must hit");
        assert_eq!(served.docs_scanned, 7);
    }

    #[test]
    fn fingerprint_collision_degrades_to_a_miss() {
        let mut cache = ResultCache::new(&cache_cfg(8, 1));
        let a = compiled("grid computing");
        // Forge a collision: a different query wearing `a`'s
        // fingerprint. Verification against the stored AST must refuse
        // to serve `a`'s response for it.
        let mut b = compiled("cloud storage");
        b.fingerprint = a.fingerprint;
        cache.insert(&a, 0, response("grid computing", 1));
        assert!(cache.get(&b, 0).is_none(), "collision served a wrong answer");
        assert_eq!(cache.counters().misses, 1);
    }

    #[test]
    fn shards_evict_fifo_and_count_it() {
        // One shard of capacity 2: the third distinct insert evicts the
        // oldest entry.
        let mut cache = ResultCache::new(&cache_cfg(2, 1));
        let (a, b, c) = (compiled("grid"), compiled("cloud"), compiled("storage"));
        cache.insert(&a, 0, response("grid", 1));
        cache.insert(&b, 0, response("cloud", 2));
        cache.insert(&c, 0, response("storage", 3));
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.counters().evicted, 1);
        assert!(cache.get(&a, 0).is_none(), "oldest entry must be the one evicted");
        assert!(cache.get(&b, 0).is_some());
        assert!(cache.get(&c, 0).is_some());
    }

    #[test]
    fn reinserting_the_same_key_does_not_evict() {
        let mut cache = ResultCache::new(&cache_cfg(2, 1));
        let (a, b) = (compiled("grid"), compiled("cloud"));
        cache.insert(&a, 0, response("grid", 1));
        cache.insert(&b, 0, response("cloud", 2));
        cache.insert(&a, 0, response("grid", 1));
        assert_eq!(cache.counters().evicted, 0, "overwrite must not evict a bystander");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn invalidate_all_empties_every_shard_and_counts_entries() {
        let mut cache = ResultCache::new(&cache_cfg(16, 4));
        for raw in ["grid", "cloud", "storage", "replication", "publication"] {
            cache.insert(&compiled(raw), 1, response(raw, 0));
        }
        assert_eq!(cache.len(), 5);
        cache.invalidate_all();
        assert!(cache.is_empty());
        assert_eq!(cache.counters().invalidated, 5);
        assert!(cache.get(&compiled("grid"), 1).is_none());
    }

    #[test]
    fn disabled_cache_is_a_silent_no_op() {
        let mut off = cache_cfg(8, 2);
        off.enabled = false;
        let mut cache = ResultCache::new(&off);
        let c = compiled("grid computing");
        cache.insert(&c, 0, response("grid computing", 1));
        assert!(cache.get(&c, 0).is_none());
        assert_eq!(cache.counters(), CacheCounters::default(), "off means not even counted");

        // capacity 0 disables just the result cache the same way.
        let mut cache = ResultCache::new(&cache_cfg(0, 2));
        cache.insert(&c, 0, response("grid computing", 1));
        assert!(cache.get(&c, 0).is_none());
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn placement_knobs_share_an_entry() {
        use crate::search::ReplicaPref;
        let mut cache = ResultCache::new(&cache_cfg(8, 2));
        let plain = compiled("grid computing");
        let placed = SearchRequest::new("grid computing")
            .prefer_replicas(ReplicaPref::SameVo)
            .deadline_ms(500)
            .compile(512, 10)
            .expect("compiles");
        assert_eq!(plain.fingerprint, placed.fingerprint);
        cache.insert(&plain, 0, response("grid computing", 9));
        assert!(
            cache.get(&placed, 0).is_some(),
            "results are placement-invariant; placement knobs must share entries"
        );
    }
}
