//! The multi-user serving layer: resident system replicas + admission
//! batching + a keep-alive HTTP front on a bounded handler pool.
//!
//! The paper's experiment is a *multi-user* workload — concurrent
//! searchers hitting grid services that are loaded once and stay
//! resident. This module is that always-on front:
//!
//! ```text
//! users ══keep-alive HTTP══> HttpServer ──round-robin──> ShardRouter
//!   (pipelined requests)   (bounded handler pool;      │
//!                           overflow shed w/ 503)      ├─> AdmissionQueue 0 ──rounds──> executor 0 (GapsSystem replica)
//!                                                      ├─> AdmissionQueue 1 ──rounds──> executor 1 (GapsSystem replica)
//!                                                      └─> ...                          (ingest fans out to every shard)
//! ```
//!
//! * [`AdmissionQueue`] coalesces concurrently arriving independent
//!   requests into `search_batch` rounds (tunable [`QueueConfig`]:
//!   max batch size, max linger; deterministic FIFO drain). Results are
//!   bit-identical to serial execution — coalescing is purely a
//!   throughput play (`tests/prop_serve_parity.rs`). A second,
//!   search-independent **ingestion lane** carries `POST /ingest`
//!   batches of publications to the same executor ([`Round`]): writes
//!   drain first and without linger, the executor feeds them to
//!   [`GapsSystem::ingest`], and the resulting [`IndexHealth`] (index
//!   epoch, searchable/buffered docs, per-source segment counts) is
//!   published back through the queue for `GET /healthz`.
//! * [`ShardRouter`] spreads searches round-robin over N admission
//!   lanes, each drained by its own executor thread owning a
//!   deterministic [`GapsSystem`] **replica** — rounds execute in
//!   parallel across shards while each shard's linger window keeps
//!   coalescing within it. Ingest fans out to *every* shard in one
//!   atomic front-order slot, so replica epochs move in lockstep and
//!   each executor's [`ResultCache`] stays coherent through the shared
//!   epoch key (see [`router`]).
//! * [`SearchServer`] owns the executor threads. Each [`GapsSystem`] is
//!   **built on and never leaves** its thread (the deploy closure runs
//!   there), which keeps the design compatible with thread-pinned
//!   scoring runtimes (PJRT handles are `!Send`).
//! * [`HttpServer`] is a `std::net` HTTP/1.1 front speaking the
//!   existing `util::json` wire forms on `POST /search`,
//!   `POST /search_batch` and `GET /healthz` (see [`http`]):
//!   keep-alive + pipelined reads by default, a bounded resident
//!   handler pool, and acceptor-side shedding with a typed 503 +
//!   `Retry-After` once every handler is occupied.
//! * Each executor owns a fingerprint-keyed [`ResultCache`] (see
//!   [`cache`]) and compiles through its system's plan cache: repeats
//!   of a hot query skip parse + plan, and result-cache hits skip the
//!   grid round entirely. Entries are keyed on the normalized-AST
//!   fingerprint + index epoch and dropped wholesale when an ingest
//!   round moves the epoch. Identical concurrent submissions
//!   single-flight in the [`AdmissionQueue`]: one execution, fanned-out
//!   results ([`QueueStats::singleflight`]).
//!
//! The `gaps serve` subcommand wires all of it together; embedders can
//! use the pieces directly:
//!
//! ```
//! use std::time::Duration;
//! use gaps::config::GapsConfig;
//! use gaps::coordinator::GapsSystem;
//! use gaps::search::SearchRequest;
//! use gaps::serve::{QueueConfig, SearchServer};
//!
//! let mut cfg = GapsConfig::default();
//! cfg.workload.num_docs = 400;
//! cfg.workload.sub_shards = 4;
//! cfg.search.use_xla = false;
//! let server = SearchServer::start(
//!     QueueConfig {
//!         max_batch: 8,
//!         max_linger: Duration::from_millis(1),
//!         ..QueueConfig::default()
//!     },
//!     move || GapsSystem::deploy(cfg, 3),
//! )?;
//! let resp = server.queue().submit(SearchRequest::new("grid computing"))?;
//! assert!(resp.response_s() > 0.0);
//! server.shutdown();
//! # Ok::<(), gaps::search::SearchError>(())
//! ```

pub mod cache;
pub mod http;
pub mod queue;
pub mod router;

pub use cache::{CacheCounters, ResultCache};
pub use http::{status_for, HttpConfig, HttpServer, ShutdownHandle};
pub use queue::{
    retry_after_hint, AdmissionQueue, AdmittedBatch, IngestBatch, IngestTicket, QueueConfig,
    QueueStats, ResponseTicket, Round,
};
pub use router::{HealthSnapshot, HttpCounters, HttpStats, ShardRouter};

use std::path::Path;
use std::sync::{mpsc, Arc};
use std::thread;

use crate::config::ObsConfig;
use crate::coordinator::{GapsSystem, IndexHealth};
use crate::obs::{Registry, SlowLog};
use crate::search::SearchError;

/// Shared observability plumbing for one serving plane: the metrics
/// [`Registry`] every queue/executor/HTTP counter registers on (rendered
/// by `GET /metrics`), the [`SlowLog`] ring behind `GET /debug/slow`,
/// and the slow-query threshold. Clones share the same registry and
/// ring (`Arc`s), so the front and every executor thread publish into
/// one sink.
#[derive(Clone)]
pub struct ServeObs {
    /// Metric registry for the whole serving plane.
    pub registry: Arc<Registry>,
    /// Bounded ring of slow-query records.
    pub slow: Arc<SlowLog>,
    /// Requests whose total (queued + executed) time reaches this many
    /// milliseconds are recorded in the slow log.
    pub slow_query_ms: u64,
}

impl Default for ServeObs {
    fn default() -> ServeObs {
        ServeObs {
            registry: Arc::new(Registry::new()),
            slow: Arc::new(SlowLog::new(128)),
            slow_query_ms: 500,
        }
    }
}

impl ServeObs {
    /// Build from the `obs.*` config section. A non-empty
    /// `slow_log_file` mirrors slow-query records to that file as JSONL
    /// (appending); if the file cannot be opened the mirror is dropped
    /// and the in-memory ring still works.
    pub fn from_config(cfg: &ObsConfig) -> ServeObs {
        let slow = if cfg.slow_log_file.is_empty() {
            SlowLog::new(cfg.slow_log_capacity)
        } else {
            SlowLog::with_file(cfg.slow_log_capacity, Path::new(&cfg.slow_log_file))
                .unwrap_or_else(|_| SlowLog::new(cfg.slow_log_capacity))
        };
        ServeObs {
            registry: Arc::new(Registry::new()),
            slow: Arc::new(slow),
            slow_query_ms: cfg.slow_query_ms,
        }
    }
}

/// A running serving layer: N admission lanes behind a [`ShardRouter`],
/// each drained by an executor thread that owns a deployed
/// [`GapsSystem`] replica.
///
/// Dropping (or [`SearchServer::shutdown`]) closes every lane, drains
/// pending rounds, and joins the executors.
pub struct SearchServer {
    router: Arc<ShardRouter>,
    executors: Vec<thread::JoinHandle<()>>,
}

impl SearchServer {
    /// Boot a single-shard serving layer. `deploy` runs **on the
    /// executor thread** and builds the system that will answer every
    /// round — so the system never has to be `Send`, and deployment cost
    /// (corpus analysis, index builds, pool spawn) is paid exactly once
    /// for the server's lifetime. A deploy failure is returned here, not
    /// hidden in the executor.
    pub fn start<F>(cfg: QueueConfig, deploy: F) -> Result<SearchServer, SearchError>
    where
        F: FnOnce() -> Result<GapsSystem, SearchError> + Send + 'static,
    {
        let queue = Arc::new(AdmissionQueue::new(cfg));
        let exec_queue = Arc::clone(&queue);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), SearchError>>();
        let executor = thread::Builder::new()
            .name("gaps-serve-exec".into())
            .spawn(move || match deploy() {
                Ok(mut sys) => {
                    // Publish before the ready signal so callers see an
                    // index health from the instant `start` returns.
                    exec_queue.publish_index_health(sys.index_health());
                    let _ = ready_tx.send(Ok(()));
                    queue::run(&exec_queue, &mut sys);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(SearchServer {
                router: Arc::new(ShardRouter::single(queue)),
                executors: vec![executor],
            }),
            Ok(Err(e)) => {
                let _ = executor.join();
                Err(e)
            }
            Err(_) => {
                let _ = executor.join();
                Err(SearchError::internal("serve executor died during deployment"))
            }
        }
    }

    /// Boot a sharded serving layer: `shards` executor threads (clamped
    /// up to 1), each running `deploy(shard_index)` **on its own
    /// thread** and draining its own admission lane. Searches route
    /// round-robin across the lanes; ingest fans out to all of them.
    ///
    /// `deploy` must build **identical deterministic replicas** — the
    /// cheap way is [`GapsSystem::from_deployment`] over one shared
    /// [`crate::coordinator::Deployment`] — because shard routing is
    /// load balancing, not partitioning: any shard must answer any
    /// query bit-identically, and lockstep ingest keeps the replicas
    /// identical afterwards (`tests/prop_serve_parity.rs` pins this
    /// against the serial single-shard oracle).
    ///
    /// Any deploy failure surfaces here: every shard is then shut down
    /// and joined before the first error is returned.
    pub fn start_sharded<F>(
        cfg: QueueConfig,
        shards: usize,
        deploy: F,
    ) -> Result<SearchServer, SearchError>
    where
        F: Fn(usize) -> Result<GapsSystem, SearchError> + Send + Sync + 'static,
    {
        SearchServer::start_sharded_with_obs(cfg, shards, ServeObs::default(), deploy)
    }

    /// [`SearchServer::start_sharded`] with an explicit observability
    /// sink: every shard's admission counters register on
    /// `obs.registry` under a `shard` label, executors run the traced
    /// loop ([`queue::run_with_obs`]) recording per-stage latency
    /// histograms and slow queries, and the returned router shares the
    /// same sink (`router().obs()`) for `GET /metrics`, `GET
    /// /debug/slow`, and atomic `/healthz` snapshots.
    pub fn start_sharded_with_obs<F>(
        cfg: QueueConfig,
        shards: usize,
        obs: ServeObs,
        deploy: F,
    ) -> Result<SearchServer, SearchError>
    where
        F: Fn(usize) -> Result<GapsSystem, SearchError> + Send + Sync + 'static,
    {
        let shards = shards.max(1);
        let deploy = Arc::new(deploy);
        let mut queues = Vec::with_capacity(shards);
        let mut executors = Vec::with_capacity(shards);
        let mut ready = Vec::with_capacity(shards);
        for i in 0..shards {
            let queue = Arc::new(AdmissionQueue::with_registry(cfg, &obs.registry, Some(i)));
            let exec_queue = Arc::clone(&queue);
            let deploy = Arc::clone(&deploy);
            let exec_obs = obs.clone();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<(), SearchError>>();
            let spawned = thread::Builder::new()
                .name(format!("gaps-serve-exec-{i}"))
                .spawn(move || match deploy(i) {
                    Ok(mut sys) => {
                        exec_queue.publish_index_health(sys.index_health());
                        let _ = ready_tx.send(Ok(()));
                        queue::run_with_obs(&exec_queue, &mut sys, &exec_obs, i);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                });
            match spawned {
                Ok(handle) => {
                    queues.push(queue);
                    executors.push(handle);
                    ready.push(ready_rx);
                }
                Err(e) => {
                    for q in &queues {
                        q.shutdown();
                    }
                    for h in executors {
                        let _ = h.join();
                    }
                    return Err(e.into());
                }
            }
        }
        // Wait for every shard to deploy (they deploy concurrently, so
        // the slowest one bounds startup, not the sum).
        let mut failure: Option<SearchError> = None;
        for rx in ready {
            match rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if failure.is_none() {
                        failure = Some(e);
                    }
                }
                Err(_) => {
                    if failure.is_none() {
                        failure =
                            Some(SearchError::internal("serve executor died during deployment"));
                    }
                }
            }
        }
        if let Some(e) = failure {
            for q in &queues {
                q.shutdown();
            }
            for h in executors {
                let _ = h.join();
            }
            return Err(e);
        }
        Ok(SearchServer { router: Arc::new(ShardRouter::with_obs(queues, obs)), executors })
    }

    /// The shard router (share it with front-ends / submitters).
    pub fn router(&self) -> Arc<ShardRouter> {
        Arc::clone(&self.router)
    }

    /// The first shard's admission queue. For a single-shard server this
    /// is *the* queue (the historical embedding API); on a sharded
    /// server prefer [`SearchServer::router`], which balances across
    /// lanes.
    pub fn queue(&self) -> Arc<AdmissionQueue> {
        Arc::clone(self.router.shard(0))
    }

    /// Admission counters snapshot, aggregated across shards
    /// ([`QueueStats::absorb`]).
    pub fn stats(&self) -> QueueStats {
        self.router.stats()
    }

    /// Last index health the executors published (epoch, searchable and
    /// buffered docs, per-source segment counts). Always `Some` once
    /// `start` returned, since each executor publishes before its first
    /// round. Replicas stay in lockstep, so shard 0 speaks for all.
    pub fn index_health(&self) -> Option<IndexHealth> {
        self.router.index_health()
    }

    /// Close every lane, drain pending rounds, join the executors.
    pub fn shutdown(mut self) {
        self.finish();
    }

    fn finish(&mut self) {
        self.router.shutdown();
        for handle in self.executors.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for SearchServer {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GapsConfig;
    use crate::search::SearchRequest;
    use std::time::Duration;

    fn small_cfg() -> GapsConfig {
        let mut cfg = GapsConfig::default();
        cfg.workload.num_docs = 400;
        cfg.workload.sub_shards = 4;
        cfg.search.use_xla = false;
        cfg
    }

    #[test]
    fn server_answers_submissions() {
        let cfg = small_cfg();
        let server = SearchServer::start(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::deploy(cfg, 3),
        )
        .unwrap();
        let resp = server.queue().submit(SearchRequest::new("grid computing")).unwrap();
        assert!(resp.jobs >= 1);
        let err = server.queue().submit(SearchRequest::new("the of and")).unwrap_err();
        assert_eq!(err.kind(), "parse");
        let stats = server.stats();
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.executed, 2);
        server.shutdown();
    }

    #[test]
    fn deploy_failure_surfaces_at_start() {
        let mut cfg = small_cfg();
        cfg.workload.num_docs = 1; // corpus too small for its sub-shards
        let err = SearchServer::start(QueueConfig::default(), move || {
            GapsSystem::deploy(cfg, 3)
        })
        .unwrap_err();
        assert_eq!(err.kind(), "invalid-config");
    }

    #[test]
    fn sharded_server_answers_on_every_shard_identically() {
        use crate::coordinator::Deployment;
        let cfg = small_cfg();
        let dep = Arc::new(Deployment::build(&cfg, 3).unwrap());
        let dep_f = Arc::clone(&dep);
        let cfg_f = cfg.clone();
        let server = SearchServer::start_sharded(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            3,
            move |_shard| GapsSystem::from_deployment(cfg_f.clone(), Arc::clone(&dep_f)),
        )
        .unwrap();
        assert_eq!(server.router().num_shards(), 3);

        // Six sequential submissions walk the round-robin twice over all
        // three replicas; every answer must be bit-identical to the
        // serial oracle on the same deployment.
        let mut oracle = GapsSystem::from_deployment(cfg, Arc::clone(&dep)).unwrap();
        let serial = oracle.search_request(SearchRequest::new("grid computing")).unwrap();
        for _ in 0..6 {
            let served =
                server.router().submit(SearchRequest::new("grid computing")).unwrap();
            let served_ids: Vec<(u64, u32)> =
                served.hits.iter().map(|h| (h.global_id, h.score.to_bits())).collect();
            let serial_ids: Vec<(u64, u32)> =
                serial.hits.iter().map(|h| (h.global_id, h.score.to_bits())).collect();
            assert_eq!(served_ids, serial_ids, "replica answers must match the oracle");
            assert_eq!(served.candidates, serial.candidates);
            assert_eq!(served.docs_scanned, serial.docs_scanned);
        }
        let per_shard = server.router().per_shard_stats();
        assert_eq!(per_shard.len(), 3);
        assert!(
            per_shard.iter().all(|s| s.submitted == 2),
            "round-robin must have spread 6 submissions 2-2-2: {per_shard:?}"
        );
        assert_eq!(server.stats().submitted, 6, "aggregate sums the shards");
        server.shutdown();
    }

    #[test]
    fn sharded_deploy_failure_fails_every_shard_and_surfaces() {
        let cfg = small_cfg();
        let err = SearchServer::start_sharded(QueueConfig::default(), 3, move |shard| {
            if shard == 2 {
                Err(SearchError::config("replica 2 refused to deploy"))
            } else {
                GapsSystem::deploy(cfg.clone(), 2)
            }
        })
        .unwrap_err();
        assert_eq!(err.kind(), "invalid-config");
    }

    #[test]
    fn sharded_ingest_keeps_replicas_in_lockstep() {
        use crate::coordinator::Deployment;
        use crate::corpus::Publication;
        let mut cfg = small_cfg();
        cfg.storage.seal_docs = 1; // every ingest seals -> epoch bump
        let dep = Arc::new(Deployment::build(&cfg, 3).unwrap());
        let cfg_f = cfg.clone();
        let server = SearchServer::start_sharded(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            2,
            move |_shard| GapsSystem::from_deployment(cfg_f.clone(), Arc::clone(&dep)),
        )
        .unwrap();
        let report = server
            .router()
            .submit_ingest(vec![Publication {
                id: 0,
                title: "zyzzogeton retrieval".into(),
                abstract_text: "a freshly ingested publication about zyzzogeton".into(),
                authors: "A. Author".into(),
                venue: "TEST".into(),
                year: 2026,
            }])
            .unwrap();
        assert_eq!(report.accepted, 1);
        assert!(report.epoch >= 1);

        // Both replicas must now surface the doc: four round-robin
        // submissions touch each shard twice.
        for _ in 0..4 {
            let resp = server.router().submit(SearchRequest::new("zyzzogeton")).unwrap();
            assert!(
                resp.hits.iter().any(|h| h.title.contains("zyzzogeton")),
                "every replica must see the ingested doc"
            );
        }
        // The fan-out recorded the batch on every shard's lane.
        for stats in server.router().per_shard_stats() {
            assert_eq!(stats.ingest_batches, 1, "{stats:?}");
            assert_eq!(stats.ingest_docs, 1, "{stats:?}");
        }
        server.shutdown();
    }

    #[test]
    fn ingested_docs_become_searchable_without_restart() {
        use crate::corpus::Publication;
        let mut cfg = small_cfg();
        cfg.storage.seal_docs = 1; // every ingest seals immediately
        let server = SearchServer::start(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::deploy(cfg, 3),
        )
        .unwrap();
        let h0 = server.index_health().expect("health published before start returns");
        assert_eq!(h0.epoch, 0);
        assert_eq!(h0.searchable_docs, 400);

        let docs = vec![Publication {
            id: 0, // reassigned by ingestion
            title: "zyzzogeton retrieval".into(),
            abstract_text: "a freshly ingested publication about zyzzogeton".into(),
            authors: "A. Author".into(),
            venue: "TEST".into(),
            year: 2026,
        }];
        let report = server.queue().submit_ingest(docs).unwrap();
        assert_eq!(report.accepted, 1);
        assert!(report.sealed >= 1, "seal_docs=1 must seal in the same round");
        assert!(report.epoch >= 1);

        // Searchable on the very next round — no restart, no redeploy.
        let resp = server.queue().submit(SearchRequest::new("zyzzogeton")).unwrap();
        assert!(
            resp.hits.iter().any(|h| h.title.contains("zyzzogeton")),
            "ingested doc must be retrievable after its seal"
        );
        let h = server.index_health().expect("health republished after ingest");
        assert!(h.epoch >= 1, "seal must bump the published epoch");
        assert_eq!(h.searchable_docs, 401);
        assert_eq!(h.buffered_docs, 0);
        server.shutdown();
    }

    #[test]
    fn repeated_queries_hit_the_result_cache_bit_identically() {
        let cfg = small_cfg();
        let server = SearchServer::start(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::deploy(cfg, 3),
        )
        .unwrap();
        let q = server.queue();
        let cold = q.submit(SearchRequest::new("grid computing")).unwrap();
        let warm = q.submit(SearchRequest::new("grid computing")).unwrap();
        // A reordered conjunction canonicalizes to the same AST, so it
        // shares the entry — and still echoes its own raw query text.
        let reordered = q.submit(SearchRequest::new("computing grid")).unwrap();
        let stats = server.stats();
        server.shutdown();

        for served in [&warm, &reordered] {
            let ids: Vec<(u64, u32)> =
                served.hits.iter().map(|h| (h.global_id, h.score.to_bits())).collect();
            let cold_ids: Vec<(u64, u32)> =
                cold.hits.iter().map(|h| (h.global_id, h.score.to_bits())).collect();
            assert_eq!(ids, cold_ids, "cached hits must be bit-identical to cold");
            assert_eq!(served.candidates, cold.candidates);
            assert_eq!(served.docs_scanned, cold.docs_scanned);
        }
        assert_eq!(warm.query, "grid computing");
        assert_eq!(reordered.query, "computing grid", "cache hit must echo the raw query");
        assert_eq!(stats.result_misses, 1, "only the cold request reached the grid");
        assert_eq!(stats.result_hits, 2, "{stats:?}");
        assert!(stats.plan_hits >= 1, "repeat of the identical request skips parse + plan");
    }

    #[test]
    fn disabled_cache_still_serves_correctly() {
        let mut cfg = small_cfg();
        cfg.cache.enabled = false;
        let server = SearchServer::start(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::deploy(cfg, 3),
        )
        .unwrap();
        let q = server.queue();
        let a = q.submit(SearchRequest::new("grid computing")).unwrap();
        let b = q.submit(SearchRequest::new("grid computing")).unwrap();
        let stats = server.stats();
        server.shutdown();
        let ids_a: Vec<u64> = a.hits.iter().map(|h| h.global_id).collect();
        let ids_b: Vec<u64> = b.hits.iter().map(|h| h.global_id).collect();
        assert_eq!(ids_a, ids_b);
        assert_eq!(stats.result_hits, 0, "off-switch means the cache is never consulted");
        assert_eq!(stats.plan_hits, 0);
    }

    #[test]
    fn ingest_epoch_bump_invalidates_cached_results() {
        use crate::corpus::Publication;
        let mut cfg = small_cfg();
        cfg.storage.seal_docs = 1; // every ingest seals -> epoch bump
        let server = SearchServer::start(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::deploy(cfg, 3),
        )
        .unwrap();
        let q = server.queue();
        // Warm the cache with a query whose only real match arrives by
        // ingestion afterwards: a stale hit would keep serving the
        // cached pre-ingest result.
        let pre = q.submit(SearchRequest::new("zyzzogeton")).unwrap();
        assert!(
            !pre.hits.iter().any(|h| h.title.contains("zyzzogeton")),
            "the doc must not exist pre-ingest"
        );
        let _ = q.submit(SearchRequest::new("zyzzogeton")).unwrap();
        let report = q
            .submit_ingest(vec![Publication {
                id: 0,
                title: "zyzzogeton retrieval".into(),
                abstract_text: "a freshly ingested publication about zyzzogeton".into(),
                authors: "A. Author".into(),
                venue: "TEST".into(),
                year: 2026,
            }])
            .unwrap();
        assert!(report.epoch >= 1, "seal_docs=1 must bump the epoch");
        let post = q.submit(SearchRequest::new("zyzzogeton")).unwrap();
        let stats = server.stats();
        server.shutdown();

        assert!(stats.result_hits >= 1, "the pre-ingest repeat must have hit: {stats:?}");
        assert!(stats.result_invalidated >= 1, "epoch bump must drop cached entries: {stats:?}");
        assert!(
            post.docs_scanned > pre.docs_scanned,
            "post-epoch response must see the grown corpus, not a stale hit"
        );
        assert!(
            post.hits.iter().any(|h| h.title.contains("zyzzogeton")),
            "the ingested doc must surface immediately after the bump"
        );
    }

    #[test]
    fn observability_surfaces_traces_metrics_and_slow_log() {
        use crate::coordinator::Deployment;
        let cfg = small_cfg();
        let dep = Arc::new(Deployment::build(&cfg, 3).unwrap());
        let cfg_f = cfg.clone();
        let obs = ServeObs { slow_query_ms: 0, ..ServeObs::default() };
        let server = SearchServer::start_sharded_with_obs(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            2,
            obs,
            move |_shard| GapsSystem::from_deployment(cfg_f.clone(), Arc::clone(&dep)),
        )
        .unwrap();
        let router = server.router();
        let resp = router
            .submit(SearchRequest::new("grid computing").explain(true))
            .unwrap();
        // The response carries a span tree rooted at the serving layer...
        let root = resp.trace.as_ref().expect("traced execution");
        assert_eq!(root.name, "request");
        assert!(root.find("search").is_some(), "{root:?}");
        assert!(root.find("execute").is_some(), "{root:?}");
        // ...mirrored into the explain wire form for clients.
        let stages = resp.explain.as_ref().unwrap().stages.as_ref().unwrap();
        assert_eq!(stages.name, "request");
        // slow_query_ms = 0 makes every request "slow".
        assert!(!router.obs().slow.is_empty(), "threshold 0 must log every request");
        // Metrics render with per-shard labels and per-stage histograms.
        let text = router.obs().registry.render_text();
        assert!(text.contains("gaps_request_seconds_bucket"), "{text}");
        assert!(text.contains("stage=\"search\""), "{text}");
        assert!(text.contains("gaps_queue_submitted_total{shard=\"0\"}"), "{text}");
        // The frozen health snapshot agrees with the live counters.
        let snap = router.snapshot();
        assert_eq!(snap.queue.submitted, 1);
        assert!(snap.index.is_some(), "health published before start returned");
        server.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_submissions() {
        let cfg = small_cfg();
        let server =
            SearchServer::start(QueueConfig::default(), move || GapsSystem::deploy(cfg, 2))
                .unwrap();
        let queue = server.queue();
        server.shutdown();
        assert!(queue.submit(SearchRequest::new("grid")).is_err());
    }
}
