//! The multi-user serving layer: resident system + admission-queue
//! batching + HTTP front-end.
//!
//! The paper's experiment is a *multi-user* workload — concurrent
//! searchers hitting grid services that are loaded once and stay
//! resident. This module is that always-on front:
//!
//! ```text
//! users ──HTTP──> HttpServer ──submit──> AdmissionQueue ──rounds──> executor thread
//!   (per-conn threads)        (coalesces co-arrivals)        (owns the GapsSystem,
//!                                                             calls search_batch)
//! ```
//!
//! * [`AdmissionQueue`] coalesces concurrently arriving independent
//!   requests into `search_batch` rounds (tunable [`QueueConfig`]:
//!   max batch size, max linger; deterministic FIFO drain). Results are
//!   bit-identical to serial execution — coalescing is purely a
//!   throughput play (`tests/prop_serve_parity.rs`). A second,
//!   search-independent **ingestion lane** carries `POST /ingest`
//!   batches of publications to the same executor ([`Round`]): writes
//!   drain first and without linger, the executor feeds them to
//!   [`GapsSystem::ingest`], and the resulting [`IndexHealth`] (index
//!   epoch, searchable/buffered docs, per-source segment counts) is
//!   published back through the queue for `GET /healthz`.
//! * [`SearchServer`] owns the executor thread. The [`GapsSystem`] is
//!   **built on and never leaves** that thread (the deploy closure runs
//!   there), which keeps the design compatible with thread-pinned
//!   scoring runtimes (PJRT handles are `!Send`).
//! * [`HttpServer`] is a thin `std::net` HTTP/1.1 front speaking the
//!   existing `util::json` wire forms on `POST /search`,
//!   `POST /search_batch` and `GET /healthz` (see [`http`]).
//! * The executor owns a fingerprint-keyed [`ResultCache`] (see
//!   [`cache`]) and compiles through the system's plan cache: repeats
//!   of a hot query skip parse + plan, and result-cache hits skip the
//!   grid round entirely. Entries are keyed on the normalized-AST
//!   fingerprint + index epoch and dropped wholesale when an ingest
//!   round moves the epoch. Identical concurrent submissions
//!   single-flight in the [`AdmissionQueue`]: one execution, fanned-out
//!   results ([`QueueStats::singleflight`]).
//!
//! The `gaps serve` subcommand wires all three together; embedders can
//! use the pieces directly:
//!
//! ```
//! use std::time::Duration;
//! use gaps::config::GapsConfig;
//! use gaps::coordinator::GapsSystem;
//! use gaps::search::SearchRequest;
//! use gaps::serve::{QueueConfig, SearchServer};
//!
//! let mut cfg = GapsConfig::default();
//! cfg.workload.num_docs = 400;
//! cfg.workload.sub_shards = 4;
//! cfg.search.use_xla = false;
//! let server = SearchServer::start(
//!     QueueConfig {
//!         max_batch: 8,
//!         max_linger: Duration::from_millis(1),
//!         ..QueueConfig::default()
//!     },
//!     move || GapsSystem::deploy(cfg, 3),
//! )?;
//! let resp = server.queue().submit(SearchRequest::new("grid computing"))?;
//! assert!(resp.response_s() > 0.0);
//! server.shutdown();
//! # Ok::<(), gaps::search::SearchError>(())
//! ```

pub mod cache;
pub mod http;
pub mod queue;

pub use cache::{CacheCounters, ResultCache};
pub use http::{status_for, HttpConfig, HttpServer, ShutdownHandle};
pub use queue::{
    AdmissionQueue, AdmittedBatch, IngestBatch, IngestTicket, QueueConfig, QueueStats,
    ResponseTicket, Round,
};

use std::sync::{mpsc, Arc};
use std::thread;

use crate::coordinator::{GapsSystem, IndexHealth};
use crate::search::SearchError;

/// A running serving layer: admission queue + the executor thread that
/// owns the deployed [`GapsSystem`].
///
/// Dropping (or [`SearchServer::shutdown`]) closes the queue, drains
/// pending rounds, and joins the executor.
pub struct SearchServer {
    queue: Arc<AdmissionQueue>,
    executor: Option<thread::JoinHandle<()>>,
}

impl SearchServer {
    /// Boot the serving layer. `deploy` runs **on the executor thread**
    /// and builds the system that will answer every round — so the
    /// system never has to be `Send`, and deployment cost (corpus
    /// analysis, index builds, pool spawn) is paid exactly once for the
    /// server's lifetime. A deploy failure is returned here, not hidden
    /// in the executor.
    pub fn start<F>(cfg: QueueConfig, deploy: F) -> Result<SearchServer, SearchError>
    where
        F: FnOnce() -> Result<GapsSystem, SearchError> + Send + 'static,
    {
        let queue = Arc::new(AdmissionQueue::new(cfg));
        let exec_queue = Arc::clone(&queue);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), SearchError>>();
        let executor = thread::Builder::new()
            .name("gaps-serve-exec".into())
            .spawn(move || match deploy() {
                Ok(mut sys) => {
                    // Publish before the ready signal so callers see an
                    // index health from the instant `start` returns.
                    exec_queue.publish_index_health(sys.index_health());
                    let _ = ready_tx.send(Ok(()));
                    queue::run(&exec_queue, &mut sys);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(SearchServer { queue, executor: Some(executor) }),
            Ok(Err(e)) => {
                let _ = executor.join();
                Err(e)
            }
            Err(_) => {
                let _ = executor.join();
                Err(SearchError::internal("serve executor died during deployment"))
            }
        }
    }

    /// The admission queue (share it with front-ends / submitters).
    pub fn queue(&self) -> Arc<AdmissionQueue> {
        Arc::clone(&self.queue)
    }

    /// Admission counters snapshot.
    pub fn stats(&self) -> QueueStats {
        self.queue.stats()
    }

    /// Last index health the executor published (epoch, searchable and
    /// buffered docs, per-source segment counts). Always `Some` once
    /// `start` returned, since the executor publishes before its first
    /// round.
    pub fn index_health(&self) -> Option<IndexHealth> {
        self.queue.index_health()
    }

    /// Close the queue, drain pending rounds, join the executor.
    pub fn shutdown(mut self) {
        self.finish();
    }

    fn finish(&mut self) {
        self.queue.shutdown();
        if let Some(handle) = self.executor.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for SearchServer {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GapsConfig;
    use crate::search::SearchRequest;
    use std::time::Duration;

    fn small_cfg() -> GapsConfig {
        let mut cfg = GapsConfig::default();
        cfg.workload.num_docs = 400;
        cfg.workload.sub_shards = 4;
        cfg.search.use_xla = false;
        cfg
    }

    #[test]
    fn server_answers_submissions() {
        let cfg = small_cfg();
        let server = SearchServer::start(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::deploy(cfg, 3),
        )
        .unwrap();
        let resp = server.queue().submit(SearchRequest::new("grid computing")).unwrap();
        assert!(resp.jobs >= 1);
        let err = server.queue().submit(SearchRequest::new("the of and")).unwrap_err();
        assert_eq!(err.kind(), "parse");
        let stats = server.stats();
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.executed, 2);
        server.shutdown();
    }

    #[test]
    fn deploy_failure_surfaces_at_start() {
        let mut cfg = small_cfg();
        cfg.workload.num_docs = 1; // corpus too small for its sub-shards
        let err = SearchServer::start(QueueConfig::default(), move || {
            GapsSystem::deploy(cfg, 3)
        })
        .unwrap_err();
        assert_eq!(err.kind(), "invalid-config");
    }

    #[test]
    fn ingested_docs_become_searchable_without_restart() {
        use crate::corpus::Publication;
        let mut cfg = small_cfg();
        cfg.storage.seal_docs = 1; // every ingest seals immediately
        let server = SearchServer::start(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::deploy(cfg, 3),
        )
        .unwrap();
        let h0 = server.index_health().expect("health published before start returns");
        assert_eq!(h0.epoch, 0);
        assert_eq!(h0.searchable_docs, 400);

        let docs = vec![Publication {
            id: 0, // reassigned by ingestion
            title: "zyzzogeton retrieval".into(),
            abstract_text: "a freshly ingested publication about zyzzogeton".into(),
            authors: "A. Author".into(),
            venue: "TEST".into(),
            year: 2026,
        }];
        let report = server.queue().submit_ingest(docs).unwrap();
        assert_eq!(report.accepted, 1);
        assert!(report.sealed >= 1, "seal_docs=1 must seal in the same round");
        assert!(report.epoch >= 1);

        // Searchable on the very next round — no restart, no redeploy.
        let resp = server.queue().submit(SearchRequest::new("zyzzogeton")).unwrap();
        assert!(
            resp.hits.iter().any(|h| h.title.contains("zyzzogeton")),
            "ingested doc must be retrievable after its seal"
        );
        let h = server.index_health().expect("health republished after ingest");
        assert!(h.epoch >= 1, "seal must bump the published epoch");
        assert_eq!(h.searchable_docs, 401);
        assert_eq!(h.buffered_docs, 0);
        server.shutdown();
    }

    #[test]
    fn repeated_queries_hit_the_result_cache_bit_identically() {
        let cfg = small_cfg();
        let server = SearchServer::start(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::deploy(cfg, 3),
        )
        .unwrap();
        let q = server.queue();
        let cold = q.submit(SearchRequest::new("grid computing")).unwrap();
        let warm = q.submit(SearchRequest::new("grid computing")).unwrap();
        // A reordered conjunction canonicalizes to the same AST, so it
        // shares the entry — and still echoes its own raw query text.
        let reordered = q.submit(SearchRequest::new("computing grid")).unwrap();
        let stats = server.stats();
        server.shutdown();

        for served in [&warm, &reordered] {
            let ids: Vec<(u64, u32)> =
                served.hits.iter().map(|h| (h.global_id, h.score.to_bits())).collect();
            let cold_ids: Vec<(u64, u32)> =
                cold.hits.iter().map(|h| (h.global_id, h.score.to_bits())).collect();
            assert_eq!(ids, cold_ids, "cached hits must be bit-identical to cold");
            assert_eq!(served.candidates, cold.candidates);
            assert_eq!(served.docs_scanned, cold.docs_scanned);
        }
        assert_eq!(warm.query, "grid computing");
        assert_eq!(reordered.query, "computing grid", "cache hit must echo the raw query");
        assert_eq!(stats.result_misses, 1, "only the cold request reached the grid");
        assert_eq!(stats.result_hits, 2, "{stats:?}");
        assert!(stats.plan_hits >= 1, "repeat of the identical request skips parse + plan");
    }

    #[test]
    fn disabled_cache_still_serves_correctly() {
        let mut cfg = small_cfg();
        cfg.cache.enabled = false;
        let server = SearchServer::start(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::deploy(cfg, 3),
        )
        .unwrap();
        let q = server.queue();
        let a = q.submit(SearchRequest::new("grid computing")).unwrap();
        let b = q.submit(SearchRequest::new("grid computing")).unwrap();
        let stats = server.stats();
        server.shutdown();
        let ids_a: Vec<u64> = a.hits.iter().map(|h| h.global_id).collect();
        let ids_b: Vec<u64> = b.hits.iter().map(|h| h.global_id).collect();
        assert_eq!(ids_a, ids_b);
        assert_eq!(stats.result_hits, 0, "off-switch means the cache is never consulted");
        assert_eq!(stats.plan_hits, 0);
    }

    #[test]
    fn ingest_epoch_bump_invalidates_cached_results() {
        use crate::corpus::Publication;
        let mut cfg = small_cfg();
        cfg.storage.seal_docs = 1; // every ingest seals -> epoch bump
        let server = SearchServer::start(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::deploy(cfg, 3),
        )
        .unwrap();
        let q = server.queue();
        // Warm the cache with a query whose only real match arrives by
        // ingestion afterwards: a stale hit would keep serving the
        // cached pre-ingest result.
        let pre = q.submit(SearchRequest::new("zyzzogeton")).unwrap();
        assert!(
            !pre.hits.iter().any(|h| h.title.contains("zyzzogeton")),
            "the doc must not exist pre-ingest"
        );
        let _ = q.submit(SearchRequest::new("zyzzogeton")).unwrap();
        let report = q
            .submit_ingest(vec![Publication {
                id: 0,
                title: "zyzzogeton retrieval".into(),
                abstract_text: "a freshly ingested publication about zyzzogeton".into(),
                authors: "A. Author".into(),
                venue: "TEST".into(),
                year: 2026,
            }])
            .unwrap();
        assert!(report.epoch >= 1, "seal_docs=1 must bump the epoch");
        let post = q.submit(SearchRequest::new("zyzzogeton")).unwrap();
        let stats = server.stats();
        server.shutdown();

        assert!(stats.result_hits >= 1, "the pre-ingest repeat must have hit: {stats:?}");
        assert!(stats.result_invalidated >= 1, "epoch bump must drop cached entries: {stats:?}");
        assert!(
            post.docs_scanned > pre.docs_scanned,
            "post-epoch response must see the grown corpus, not a stale hit"
        );
        assert!(
            post.hits.iter().any(|h| h.title.contains("zyzzogeton")),
            "the ingested doc must surface immediately after the bump"
        );
    }

    #[test]
    fn shutdown_rejects_new_submissions() {
        let cfg = small_cfg();
        let server =
            SearchServer::start(QueueConfig::default(), move || GapsSystem::deploy(cfg, 2))
                .unwrap();
        let queue = server.queue();
        server.shutdown();
        assert!(queue.submit(SearchRequest::new("grid")).is_err());
    }
}
