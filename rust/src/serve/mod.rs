//! The multi-user serving layer: resident system + admission-queue
//! batching + HTTP front-end.
//!
//! The paper's experiment is a *multi-user* workload — concurrent
//! searchers hitting grid services that are loaded once and stay
//! resident. This module is that always-on front:
//!
//! ```text
//! users ──HTTP──> HttpServer ──submit──> AdmissionQueue ──rounds──> executor thread
//!   (per-conn threads)        (coalesces co-arrivals)        (owns the GapsSystem,
//!                                                             calls search_batch)
//! ```
//!
//! * [`AdmissionQueue`] coalesces concurrently arriving independent
//!   requests into `search_batch` rounds (tunable [`QueueConfig`]:
//!   max batch size, max linger; deterministic FIFO drain). Results are
//!   bit-identical to serial execution — coalescing is purely a
//!   throughput play (`tests/prop_serve_parity.rs`).
//! * [`SearchServer`] owns the executor thread. The [`GapsSystem`] is
//!   **built on and never leaves** that thread (the deploy closure runs
//!   there), which keeps the design compatible with thread-pinned
//!   scoring runtimes (PJRT handles are `!Send`).
//! * [`HttpServer`] is a thin `std::net` HTTP/1.1 front speaking the
//!   existing `util::json` wire forms on `POST /search`,
//!   `POST /search_batch` and `GET /healthz` (see [`http`]).
//!
//! The `gaps serve` subcommand wires all three together; embedders can
//! use the pieces directly:
//!
//! ```
//! use std::time::Duration;
//! use gaps::config::GapsConfig;
//! use gaps::coordinator::GapsSystem;
//! use gaps::search::SearchRequest;
//! use gaps::serve::{QueueConfig, SearchServer};
//!
//! let mut cfg = GapsConfig::default();
//! cfg.workload.num_docs = 400;
//! cfg.workload.sub_shards = 4;
//! cfg.search.use_xla = false;
//! let server = SearchServer::start(
//!     QueueConfig {
//!         max_batch: 8,
//!         max_linger: Duration::from_millis(1),
//!         ..QueueConfig::default()
//!     },
//!     move || GapsSystem::deploy(cfg, 3),
//! )?;
//! let resp = server.queue().submit(SearchRequest::new("grid computing"))?;
//! assert!(resp.response_s() > 0.0);
//! server.shutdown();
//! # Ok::<(), gaps::search::SearchError>(())
//! ```

pub mod http;
pub mod queue;

pub use http::{status_for, HttpConfig, HttpServer, ShutdownHandle};
pub use queue::{AdmissionQueue, AdmittedBatch, QueueConfig, QueueStats, ResponseTicket};

use std::sync::{mpsc, Arc};
use std::thread;

use crate::coordinator::GapsSystem;
use crate::search::SearchError;

/// A running serving layer: admission queue + the executor thread that
/// owns the deployed [`GapsSystem`].
///
/// Dropping (or [`SearchServer::shutdown`]) closes the queue, drains
/// pending rounds, and joins the executor.
pub struct SearchServer {
    queue: Arc<AdmissionQueue>,
    executor: Option<thread::JoinHandle<()>>,
}

impl SearchServer {
    /// Boot the serving layer. `deploy` runs **on the executor thread**
    /// and builds the system that will answer every round — so the
    /// system never has to be `Send`, and deployment cost (corpus
    /// analysis, index builds, pool spawn) is paid exactly once for the
    /// server's lifetime. A deploy failure is returned here, not hidden
    /// in the executor.
    pub fn start<F>(cfg: QueueConfig, deploy: F) -> Result<SearchServer, SearchError>
    where
        F: FnOnce() -> Result<GapsSystem, SearchError> + Send + 'static,
    {
        let queue = Arc::new(AdmissionQueue::new(cfg));
        let exec_queue = Arc::clone(&queue);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), SearchError>>();
        let executor = thread::Builder::new()
            .name("gaps-serve-exec".into())
            .spawn(move || match deploy() {
                Ok(mut sys) => {
                    let _ = ready_tx.send(Ok(()));
                    queue::run(&exec_queue, &mut sys);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(SearchServer { queue, executor: Some(executor) }),
            Ok(Err(e)) => {
                let _ = executor.join();
                Err(e)
            }
            Err(_) => {
                let _ = executor.join();
                Err(SearchError::internal("serve executor died during deployment"))
            }
        }
    }

    /// The admission queue (share it with front-ends / submitters).
    pub fn queue(&self) -> Arc<AdmissionQueue> {
        Arc::clone(&self.queue)
    }

    /// Admission counters snapshot.
    pub fn stats(&self) -> QueueStats {
        self.queue.stats()
    }

    /// Close the queue, drain pending rounds, join the executor.
    pub fn shutdown(mut self) {
        self.finish();
    }

    fn finish(&mut self) {
        self.queue.shutdown();
        if let Some(handle) = self.executor.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for SearchServer {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GapsConfig;
    use crate::search::SearchRequest;
    use std::time::Duration;

    fn small_cfg() -> GapsConfig {
        let mut cfg = GapsConfig::default();
        cfg.workload.num_docs = 400;
        cfg.workload.sub_shards = 4;
        cfg.search.use_xla = false;
        cfg
    }

    #[test]
    fn server_answers_submissions() {
        let cfg = small_cfg();
        let server = SearchServer::start(
            QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::deploy(cfg, 3),
        )
        .unwrap();
        let resp = server.queue().submit(SearchRequest::new("grid computing")).unwrap();
        assert!(resp.jobs >= 1);
        let err = server.queue().submit(SearchRequest::new("the of and")).unwrap_err();
        assert_eq!(err.kind(), "parse");
        let stats = server.stats();
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.executed, 2);
        server.shutdown();
    }

    #[test]
    fn deploy_failure_surfaces_at_start() {
        let mut cfg = small_cfg();
        cfg.workload.num_docs = 1; // corpus too small for its sub-shards
        let err = SearchServer::start(QueueConfig::default(), move || {
            GapsSystem::deploy(cfg, 3)
        })
        .unwrap_err();
        assert_eq!(err.kind(), "invalid-config");
    }

    #[test]
    fn shutdown_rejects_new_submissions() {
        let cfg = small_cfg();
        let server =
            SearchServer::start(QueueConfig::default(), move || GapsSystem::deploy(cfg, 2))
                .unwrap();
        let queue = server.queue();
        server.shutdown();
        assert!(queue.submit(SearchRequest::new("grid")).is_err());
    }
}
