"""Make `compile.*` importable whether pytest runs from python/ or the
repo root (the Makefile uses `cd python`; the top-level validation command
uses `pytest python/tests/`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
