"""AOT path: lowering produces parseable HLO text with the right ABI."""

import json
import os
import tempfile

import jax
import numpy as np

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


class TestLowering:
    def test_hlo_text_structure(self):
        text = aot.to_hlo_text(aot.lower_variant(q=1, d=256, f=512, k=32))
        assert "HloModule" in text
        assert "ENTRY" in text
        # 4 parameters: doc_tf, len_norm, field_w, qw
        assert "parameter(3)" in text and "parameter(4)" not in text
        # tuple output with both scores (f32) and indices (s32)
        assert "s32[1,32]" in text and "f32[1,32]" in text

    def test_lowered_executes_and_matches_ref(self):
        """The exact computation we serialize matches the oracle."""
        lowered = aot.lower_variant(q=2, d=256, f=512, k=32)
        compiled = lowered.compile()
        args = model.example_inputs(2, 256, 512, seed=7)
        v, i = compiled(*args)
        rv, ri = ref.rank_ref(*args, k=32)
        np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-5, atol=1e-5)

    def test_variant_names_unique(self):
        names = [aot.variant_name(**v) for v in aot.VARIANTS]
        assert len(names) == len(set(names))

    def test_build_all_writes_manifest(self):
        with tempfile.TemporaryDirectory() as td:
            manifest = aot.build_all(td)
            files = set(os.listdir(td))
            assert "manifest.json" in files
            for a in manifest["artifacts"]:
                assert a["file"] in files
                assert a["nf"] == model.NUM_FIELDS
            with open(os.path.join(td, "manifest.json")) as fh:
                loaded = json.load(fh)
            assert loaded["abi"]["return_tuple"] is True
            assert loaded["abi"]["k1"] == model.DEFAULT_K1

    def test_hlo_text_has_no_64bit_ids_issue(self):
        """Text interchange: ids must be parseable (regression guard for the
        xla_extension 0.5.1 32-bit-id limitation)."""
        text = aot.to_hlo_text(aot.lower_variant(q=1, d=256, f=512, k=32))
        # The text parser reassigns ids; just assert it's plain ASCII text.
        assert text.isascii()
        assert not text.startswith("\x08")  # not a binary proto
