"""L1 correctness: Pallas BM25F kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compile path: every artifact
the rust runtime executes is a lowering of `model.rank_candidates`, which
wraps `kernels.bm25.bm25_scores`; if the kernel matches `kernels.ref` for
all shapes/dtypes, the artifacts are trustworthy.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import bm25, ref

jax.config.update("jax_platform_name", "cpu")


def _rand_inputs(rng, nf, d, f, q, dtype=np.float32, sparsity=0.05):
    doc_tf = (rng.poisson(0.1, (nf, d, f)) * (rng.random((nf, d, f)) < sparsity)).astype(
        dtype
    )
    lens = np.maximum(rng.poisson(40.0, (nf, d)), 1).astype(np.float32)
    b = 0.75
    len_norm = (1.0 / (1.0 - b + b * lens / lens.mean())).astype(dtype)
    field_w = rng.uniform(0.25, 2.5, (nf,)).astype(np.float32)
    qw = (rng.uniform(0, 3, (q, f)) * (rng.random((q, f)) < 0.02)).astype(dtype)
    return doc_tf, len_norm, field_w, qw


# ---------------------------------------------------------------- unit tests


class TestKernelBasics:
    def test_matches_ref_default_shape(self):
        rng = np.random.default_rng(0)
        args = _rand_inputs(rng, 4, 512, 256, 4)
        got = bm25.bm25_scores(*[jnp.asarray(a) for a in args], block_d=128)
        want = ref.bm25_scores_ref(*[jnp.asarray(a) for a in args])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_single_block(self):
        """D == block_d: grid of one step."""
        rng = np.random.default_rng(1)
        args = _rand_inputs(rng, 4, 128, 128, 2)
        got = bm25.bm25_scores(*[jnp.asarray(a) for a in args], block_d=128)
        want = ref.bm25_scores_ref(*[jnp.asarray(a) for a in args])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_block_larger_than_d_is_clamped(self):
        rng = np.random.default_rng(2)
        args = _rand_inputs(rng, 2, 64, 64, 1)
        got = bm25.bm25_scores(*[jnp.asarray(a) for a in args], block_d=512)
        want = ref.bm25_scores_ref(*[jnp.asarray(a) for a in args])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_indivisible_block_raises(self):
        rng = np.random.default_rng(3)
        args = _rand_inputs(rng, 2, 100, 64, 1)
        with pytest.raises(ValueError, match="divisible"):
            bm25.bm25_scores(*[jnp.asarray(a) for a in args], block_d=64)

    def test_shape_validation(self):
        rng = np.random.default_rng(4)
        doc_tf, len_norm, field_w, qw = _rand_inputs(rng, 2, 64, 64, 1)
        with pytest.raises(ValueError, match="len_norm"):
            bm25.bm25_scores(
                jnp.asarray(doc_tf),
                jnp.asarray(len_norm[:, :32]),
                jnp.asarray(field_w),
                jnp.asarray(qw),
            )
        with pytest.raises(ValueError, match="field_w"):
            bm25.bm25_scores(
                jnp.asarray(doc_tf),
                jnp.asarray(len_norm),
                jnp.asarray(field_w[:1]),
                jnp.asarray(qw),
            )
        with pytest.raises(ValueError, match="feature"):
            bm25.bm25_scores(
                jnp.asarray(doc_tf),
                jnp.asarray(len_norm),
                jnp.asarray(field_w),
                jnp.asarray(qw[:, :32]),
            )

    def test_zero_padding_scores_zero(self):
        """Padded docs (tf == 0, len_norm == 0) must score exactly 0."""
        rng = np.random.default_rng(5)
        doc_tf, len_norm, field_w, qw = _rand_inputs(rng, 4, 256, 128, 3)
        doc_tf[:, 100:, :] = 0.0
        len_norm[:, 100:] = 0.0
        got = np.asarray(
            bm25.bm25_scores(
                jnp.asarray(doc_tf),
                jnp.asarray(len_norm),
                jnp.asarray(field_w),
                jnp.asarray(qw),
                block_d=128,
            )
        )
        assert (got[:, 100:] == 0.0).all()

    def test_scores_nonnegative(self):
        rng = np.random.default_rng(6)
        args = _rand_inputs(rng, 4, 256, 128, 4)
        got = np.asarray(bm25.bm25_scores(*[jnp.asarray(a) for a in args], block_d=64))
        assert (got >= 0.0).all()

    def test_monotonic_in_field_weight(self):
        """Raising a field weight must not lower any score."""
        rng = np.random.default_rng(7)
        doc_tf, len_norm, field_w, qw = _rand_inputs(rng, 4, 128, 64, 2)
        lo = np.asarray(
            bm25.bm25_scores(
                jnp.asarray(doc_tf), jnp.asarray(len_norm), jnp.asarray(field_w), jnp.asarray(qw)
            )
        )
        field_w2 = field_w.copy()
        field_w2[0] *= 2.0
        hi = np.asarray(
            bm25.bm25_scores(
                jnp.asarray(doc_tf), jnp.asarray(len_norm), jnp.asarray(field_w2), jnp.asarray(qw)
            )
        )
        assert (hi >= lo - 1e-6).all()

    def test_saturation_bounds(self):
        """Each term's contribution is capped at (k1+1) * qw -> score bounded."""
        rng = np.random.default_rng(8)
        doc_tf, len_norm, field_w, qw = _rand_inputs(rng, 4, 128, 64, 2)
        doc_tf *= 1000.0  # huge term counts
        k1 = 1.2
        got = np.asarray(
            bm25.bm25_scores(
                jnp.asarray(doc_tf),
                jnp.asarray(len_norm),
                jnp.asarray(field_w),
                jnp.asarray(qw),
                k1=k1,
            )
        )
        bound = (k1 + 1.0) * qw.sum(axis=1, keepdims=True) + 1e-4
        assert (got <= bound).all()

    def test_bf16_tiles_close_to_f32(self):
        """bf16 doc tiles (the MXU-friendly dtype) stay close to f32 ref."""
        rng = np.random.default_rng(9)
        doc_tf, len_norm, field_w, qw = _rand_inputs(rng, 4, 256, 128, 2)
        got = np.asarray(
            bm25.bm25_scores(
                jnp.asarray(doc_tf, dtype=jnp.bfloat16),
                jnp.asarray(len_norm, dtype=jnp.bfloat16),
                jnp.asarray(field_w),
                jnp.asarray(qw),
                block_d=128,
            )
        )
        want = np.asarray(
            ref.bm25_scores_ref(
                jnp.asarray(doc_tf), jnp.asarray(len_norm), jnp.asarray(field_w), jnp.asarray(qw)
            )
        )
        np.testing.assert_allclose(got, want, rtol=0.05, atol=0.05)


# ---------------------------------------------------------- hypothesis sweep


@settings(max_examples=25, deadline=None)
@given(
    nf=st.integers(1, 4),
    dpow=st.integers(4, 8),  # D in {16..256}
    fpow=st.integers(4, 7),  # F in {16..128}
    q=st.integers(1, 8),
    block_pow=st.integers(4, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_kernel_matches_ref(nf, dpow, fpow, q, block_pow, seed):
    d, f, block_d = 2**dpow, 2**fpow, 2**block_pow
    if d % min(block_d, d) != 0:
        return
    rng = np.random.default_rng(seed)
    args = [jnp.asarray(a) for a in _rand_inputs(rng, nf, d, f, q)]
    got = bm25.bm25_scores(*args, block_d=block_d)
    want = ref.bm25_scores_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    k1=st.floats(0.1, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_k1_sweep(k1, seed):
    rng = np.random.default_rng(seed)
    args = [jnp.asarray(a) for a in _rand_inputs(rng, 3, 64, 32, 2)]
    got = bm25.bm25_scores(*args, k1=k1, block_d=32)
    want = ref.bm25_scores_ref(*args, k1=k1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
