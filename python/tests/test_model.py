"""L2 correctness: rank_candidates (scoring + top-k) and its invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


class TestRankCandidates:
    def test_topk_matches_ref(self):
        args = model.example_inputs(4, 512, 128, seed=1)
        v, i = model.rank_candidates(*args, k=16, block_d=128)
        rv, ri = ref.rank_ref(*args, k=16)
        np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-5, atol=1e-5)

    def test_topk_matches_numpy_argsort(self):
        args = model.example_inputs(2, 256, 64, seed=2)
        v, i = model.rank_candidates(*args, k=8, block_d=128)
        scores = np.asarray(ref.bm25_scores_ref(*args))
        for q in range(scores.shape[0]):
            want = np.sort(scores[q])[::-1][:8]
            np.testing.assert_allclose(np.asarray(v)[q], want, rtol=1e-5, atol=1e-5)

    def test_indices_are_valid_and_consistent(self):
        args = model.example_inputs(3, 256, 64, seed=3)
        v, i = model.rank_candidates(*args, k=8, block_d=128)
        v, i = np.asarray(v), np.asarray(i)
        scores = np.asarray(ref.bm25_scores_ref(*args))
        assert i.dtype == np.int32
        assert ((i >= 0) & (i < 256)).all()
        for q in range(scores.shape[0]):
            np.testing.assert_allclose(scores[q, i[q]], v[q], rtol=1e-5, atol=1e-5)

    def test_values_sorted_descending(self):
        args = model.example_inputs(4, 256, 64, seed=4)
        v, _ = model.rank_candidates(*args, k=16, block_d=128)
        v = np.asarray(v)
        assert (np.diff(v, axis=1) <= 1e-6).all()

    def test_k_clamped_to_d(self):
        args = model.example_inputs(1, 64, 32, seed=5)
        v, i = model.rank_candidates(*args, k=128, block_d=64)
        assert v.shape == (1, 64) and i.shape == (1, 64)

    def test_artifact_shapes(self):
        """Every shipped variant lowers with the declared output shapes."""
        for q, d in ((1, 256), (8, 256)):
            args = model.example_inputs(q, d, 512, seed=6)
            v, i = model.rank_candidates(*args, k=32, block_d=256)
            assert v.shape == (q, 32) and i.shape == (q, 32)


@settings(max_examples=15, deadline=None)
@given(
    q=st.integers(1, 6),
    dpow=st.integers(5, 8),
    k=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_rank_matches_ref(q, dpow, k, seed):
    d = 2**dpow
    args = model.example_inputs(q, d, 64, seed=seed)
    v, i = model.rank_candidates(*args, k=k, block_d=min(128, d))
    rv, ri = ref.rank_ref(*args, k=min(k, d))
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-4, atol=1e-4)
