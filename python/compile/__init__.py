"""GAPS build-time compile path (Layer 1 + Layer 2).

Everything in this package runs ONCE, at `make artifacts` time, and never
on the request path. It lowers the JAX/Pallas scoring stack to HLO *text*
artifacts that the rust runtime (`rust/src/runtime/`) loads through the
PJRT C API.
"""
