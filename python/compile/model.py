"""Layer-2 JAX compute graph: candidate ranking for GAPS.

`rank_candidates` is the function the AOT path lowers: it scores one packed
candidate block with the Pallas BM25F kernel (Layer 1) and reduces to an
exact top-k. One HLO artifact is produced per (Q, D, F, K) shape variant —
see `aot.py` — and the rust Search Service picks the variant that matches
its packed block.

Design notes (L2 optimisation surface, see EXPERIMENTS.md §Perf):
* top-k runs on the [Q, D] score matrix produced by the kernel — XLA fuses
  the per-block score layout with the sort, so no extra materialisation
  beyond the [Q, D] scores.
* All shapes are static; there is no host round-trip between scoring and
  top-k, and nothing is recomputed (one pass over the doc tile).
* Padded candidate rows are passed with doc_tf == 0 and len_norm == 0,
  which yields score == 0 exactly (saturation(0) == 0), so padding can
  never outrank a real match with positive query overlap; the rust merger
  additionally drops indices >= n_real.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import bm25

# Field order is part of the artifact ABI shared with rust/src/index/dense.rs.
FIELDS = ("title", "abstract", "authors", "venue")
NUM_FIELDS = len(FIELDS)

# Default BM25 constants (classic Robertson values); k1 is baked into the
# artifact at lowering time, b is folded into len_norm by the caller.
DEFAULT_K1 = 1.2


@functools.partial(jax.jit, static_argnames=("k", "k1", "block_d", "interpret"))
def rank_candidates(
    doc_tf: jax.Array,  # [NF, D, F] per-field hashed term counts
    len_norm: jax.Array,  # [NF, D]   precomputed length normalisers
    field_w: jax.Array,  # [NF]      field weights
    qw: jax.Array,  # [Q, F]    query term weights (idf * qtf)
    *,
    k: int = 32,
    k1: float = DEFAULT_K1,
    block_d: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Score a candidate block and return exact top-k per query.

    Returns (values [Q, K] f32, indices [Q, K] i32); indices are positions
    within the block — the rust merger maps them back to global doc ids.
    """
    scores = bm25.bm25_scores(
        doc_tf, len_norm, field_w, qw, k1=k1, block_d=block_d, interpret=interpret
    )
    k = min(k, scores.shape[1])
    # Exact top-k via argsort + gather rather than jax.lax.top_k: top_k
    # lowers to the modern `topk(..., largest=true)` HLO op, which the
    # xla_extension 0.5.1 text parser used by the rust runtime rejects;
    # sort + gather is ancient HLO and round-trips cleanly. argsort is
    # stable, so ties break by ascending index — matching the rust
    # scorer's tie-break exactly.
    idx = jnp.argsort(-scores, axis=1)[:, :k]
    vals = jnp.take_along_axis(scores, idx, axis=1)
    return vals, idx.astype(jnp.int32)


def example_inputs(
    q: int, d: int, f: int, nf: int = NUM_FIELDS, seed: int = 0
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Deterministic example inputs for lowering / smoke tests."""
    kq, kd, kl = jax.random.split(jax.random.PRNGKey(seed), 3)
    doc_tf = jax.random.poisson(kd, 0.02, (nf, d, f)).astype(jnp.float32)
    lens = jnp.maximum(jax.random.poisson(kl, 40.0, (nf, d)).astype(jnp.float32), 1.0)
    b = 0.75
    len_norm = 1.0 / (1.0 - b + b * lens / jnp.mean(lens))
    field_w = jnp.array([2.0, 1.0, 1.5, 0.5][:nf], dtype=jnp.float32)
    qw = jax.random.uniform(kq, (q, f), minval=0.0, maxval=3.0) * (
        jax.random.uniform(kq, (q, f)) < 0.01
    )
    return doc_tf, len_norm, field_w, qw.astype(jnp.float32)
