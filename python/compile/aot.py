"""AOT lowering: JAX/Pallas scoring stack -> HLO text artifacts.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out-dir ../artifacts

Emits one `ranker_q{Q}_d{D}_f{F}_k{K}.hlo.txt` per shape variant plus a
`manifest.json` the rust runtime uses to discover artifacts and their
shapes. HLO *text* (NOT `lowered.compile()` / `.serialize()`) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/load_hlo and DESIGN.md.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shape variants shipped to the rust runtime. Chosen to cover the Search
# Service's packing regimes:
#   * q1_d256   — interactive single query, small candidate block
#   * q1_d1024  — interactive single query, large candidate block
#   * q8_d256   — batched queries (the coordinator's dynamic batcher)
#   * q8_d1024  — batched queries, large block (bench hot path)
# F=512 hashed features per field, K=32 results per block; NF=4 fields.
VARIANTS = (
    dict(q=1, d=256, f=512, k=32),
    dict(q=1, d=1024, f=512, k=32),
    dict(q=8, d=256, f=512, k=32),
    dict(q=8, d=1024, f=512, k=32),
)

BLOCK_D = 256  # Pallas doc-tile size (see kernels/bm25.py VMEM analysis)


def variant_name(q: int, d: int, f: int, k: int) -> str:
    return f"ranker_q{q}_d{d}_f{f}_k{k}"


def lower_variant(q: int, d: int, f: int, k: int, nf: int = model.NUM_FIELDS):
    """Lower one shape variant of rank_candidates to a jax Lowered."""
    fn = functools.partial(
        model.rank_candidates,
        k=k,
        k1=model.DEFAULT_K1,
        block_d=min(BLOCK_D, d),
        interpret=True,
    )
    specs = (
        jax.ShapeDtypeStruct((nf, d, f), jnp.float32),  # doc_tf
        jax.ShapeDtypeStruct((nf, d), jnp.float32),  # len_norm
        jax.ShapeDtypeStruct((nf,), jnp.float32),  # field_w
        jax.ShapeDtypeStruct((q, f), jnp.float32),  # qw
    )
    return jax.jit(fn).lower(*specs)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 32-bit-id-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "abi": {
            "fields": list(model.FIELDS),
            "k1": model.DEFAULT_K1,
            "inputs": ["doc_tf[nf,d,f]", "len_norm[nf,d]", "field_w[nf]", "qw[q,f]"],
            "outputs": ["scores[q,k] f32", "indices[q,k] i32"],
            "return_tuple": True,
        },
        "artifacts": [],
    }
    for v in VARIANTS:
        name = variant_name(**v)
        path = os.path.join(out_dir, name + ".hlo.txt")
        text = to_hlo_text(lower_variant(**v))
        with open(path, "w") as fh:
            fh.write(text)
        manifest["artifacts"].append(
            dict(name=name, file=name + ".hlo.txt", nf=model.NUM_FIELDS, **v)
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
