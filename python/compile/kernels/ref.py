"""Pure-jnp oracle for the GAPS scoring stack.

This module is the CORRECTNESS ground truth: no Pallas, no tiling, just the
BM25F math written in the most obvious way. `python/tests` asserts the
Pallas kernel (kernels/bm25.py) matches this for every shape/dtype the
hypothesis sweep generates, and the AOT artifacts are validated against it
before they are ever handed to the rust runtime.

Scoring model (BM25F-lite, the per-field variant used by GAPS):

    wtf[f, d, t]  = tf[f, d, t] * len_norm[f, d]          per-field length-
                                                          normalised term freq
    ctf[d, t]     = sum_f field_w[f] * wtf[f, d, t]       field-combined tf
    sat[d, t]     = ctf * (k1 + 1) / (ctf + k1)           BM25 saturation
    score[q, d]   = sum_t qw[q, t] * sat[d, t]            query dot-product

where `len_norm[f, d] = 1 / (1 - b_f + b_f * len[f, d] / avglen[f])` is
precomputed by the caller (the rust Search Service), and `qw` already folds
in the IDF weights and query term counts.
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


def bm25_scores_ref(
    doc_tf: jax.Array,  # [NF, D, F] per-field hashed term counts
    len_norm: jax.Array,  # [NF, D]   precomputed length normalisers
    field_w: jax.Array,  # [NF]      field weights (title > abstract > ...)
    qw: jax.Array,  # [Q, F]    query term weights (idf * qtf)
    *,
    k1: float = 1.2,
) -> jax.Array:  # [Q, D] relevance scores
    """Reference BM25F scoring: obvious math, no tiling."""
    doc_tf = doc_tf.astype(jnp.float32)
    len_norm = len_norm.astype(jnp.float32)
    field_w = field_w.astype(jnp.float32)
    qw = qw.astype(jnp.float32)
    # Field-combined, length-normalised term frequencies: [D, F].
    ctf = jnp.einsum("f,fdt,fd->dt", field_w, doc_tf, len_norm)
    # BM25 term-frequency saturation. ctf >= 0 and k1 > 0, so no div-by-0.
    sat = ctf * (k1 + 1.0) / (ctf + k1)
    return qw @ sat.T


def rank_ref(
    doc_tf: jax.Array,
    len_norm: jax.Array,
    field_w: jax.Array,
    qw: jax.Array,
    *,
    k: int = 32,
    k1: float = 1.2,
) -> tuple[jax.Array, jax.Array]:
    """Reference ranking: full scores then exact top-k."""
    scores = bm25_scores_ref(doc_tf, len_norm, field_w, qw, k1=k1)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)
