"""Layer-1 Pallas kernel: tiled BM25F relevance scoring.

This is the compute hot-spot of GAPS: scoring a block of candidate
documents against a (small) batch of queries. The kernel is written for the
TPU memory hierarchy even though this repo executes it under
`interpret=True` on CPU (the CPU PJRT plugin cannot run Mosaic
custom-calls — see DESIGN.md §Hardware-Adaptation):

* The document axis `D` is tiled into blocks of `block_d` documents; each
  grid step stages one `[NF, block_d, F]` term-count tile plus the shared
  `[Q, F]` query tile into VMEM via the BlockSpecs below. Pallas
  double-buffers the HBM->VMEM stream across grid steps automatically.
* The per-field combine + BM25 saturation are VPU element-wise epilogues
  computed on the staged tile, and the query dot-product is a single
  `[Q, F] x [F, block_d]` contraction targeted at the MXU
  (`preferred_element_type=float32` keeps f32 accumulation for bf16 tiles).
* VMEM footprint per grid step (f32):
      NF*block_d*F + Q*F + NF*block_d + Q*block_d   floats
  e.g. NF=4, block_d=256, F=512, Q=8 -> ~2.1 MiB, comfortably inside the
  ~16 MiB VMEM budget with double buffering (x2).

Grid-search framing: `doc_tf` are hashed per-field term counts for one
*candidate block* retrieved by the inverted index on a worker node;
`qw` is the IDF-weighted query vector produced by the broker. The rust
Search Service packs candidate blocks and calls the AOT artifact built
from `model.rank_candidates`, which wraps this kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bm25_block_kernel(field_w_ref, qw_ref, doc_tf_ref, len_norm_ref, out_ref, *, k1: float):
    """One grid step: score a [NF, block_d, F] document tile for all queries.

    Refs (all staged in VMEM by the BlockSpecs in `bm25_scores`):
      field_w_ref : [NF]            field mixing weights
      qw_ref      : [Q, F]          query term weights (idf * qtf)
      doc_tf_ref  : [NF, BD, F]     per-field hashed term counts, this tile
      len_norm_ref: [NF, BD]        per-field length normalisers, this tile
      out_ref     : [Q, BD]         output scores, this tile
    """
    doc_tf = doc_tf_ref[...].astype(jnp.float32)
    len_norm = len_norm_ref[...].astype(jnp.float32)
    field_w = field_w_ref[...].astype(jnp.float32)

    # Per-field length normalisation + field combine (VPU, element-wise).
    # ctf[d, t] = sum_f field_w[f] * doc_tf[f, d, t] * len_norm[f, d]
    weighted = doc_tf * (field_w[:, None, None] * len_norm[:, :, None])
    ctf = jnp.sum(weighted, axis=0)  # [BD, F]

    # BM25 term-frequency saturation (VPU). ctf >= 0, k1 > 0: no div-by-0.
    sat = ctf * (k1 + 1.0) / (ctf + k1)  # [BD, F]

    # Query contraction (MXU): [Q, F] x [F, BD] -> [Q, BD].
    out_ref[...] = jax.lax.dot_general(
        qw_ref[...].astype(jnp.float32),
        sat,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("k1", "block_d", "interpret"))
def bm25_scores(
    doc_tf: jax.Array,  # [NF, D, F]
    len_norm: jax.Array,  # [NF, D]
    field_w: jax.Array,  # [NF]
    qw: jax.Array,  # [Q, F]
    *,
    k1: float = 1.2,
    block_d: int = 256,
    interpret: bool = True,
) -> jax.Array:  # [Q, D] f32
    """Tiled BM25F scores for a candidate block (Pallas).

    `D` must be divisible by `block_d` (the rust packer pads candidate
    blocks to the artifact shape, so this holds by construction on the
    request path; tests exercise the assertion).
    """
    nf, d, f = doc_tf.shape
    q = qw.shape[0]
    if len_norm.shape != (nf, d):
        raise ValueError(f"len_norm shape {len_norm.shape} != {(nf, d)}")
    if field_w.shape != (nf,):
        raise ValueError(f"field_w shape {field_w.shape} != {(nf,)}")
    if qw.shape[1] != f:
        raise ValueError(f"qw feature dim {qw.shape[1]} != {f}")
    block_d = min(block_d, d)
    if d % block_d != 0:
        raise ValueError(f"D={d} not divisible by block_d={block_d}")

    grid = (d // block_d,)
    return pl.pallas_call(
        functools.partial(_bm25_block_kernel, k1=k1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nf,), lambda i: (0,)),  # field_w: replicated
            pl.BlockSpec((q, f), lambda i: (0, 0)),  # qw: replicated
            pl.BlockSpec((nf, block_d, f), lambda i: (0, i, 0)),  # doc tile
            pl.BlockSpec((nf, block_d), lambda i: (0, i)),  # len tile
        ],
        out_specs=pl.BlockSpec((q, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, d), jnp.float32),
        interpret=interpret,
    )(field_w, qw, doc_tf, len_norm)


def vmem_bytes(nf: int, block_d: int, f: int, q: int, itemsize: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (single-buffered).

    Used by DESIGN.md §Perf-estimates and the L1 structural-profiling test
    to keep the chosen BlockSpecs inside the VMEM budget.
    """
    doc_tile = nf * block_d * f
    q_tile = q * f
    ln_tile = nf * block_d
    out_tile = q * block_d
    fw = nf
    return (doc_tile + q_tile + ln_tile + out_tile + fw) * itemsize


def mxu_utilization_estimate(q: int, f: int, block_d: int) -> float:
    """Estimated MXU utilisation of the contraction, for §Perf.

    The MXU is a 128x128 systolic array; a [Q, F] x [F, BD] matmul with
    Q < 128 only fills Q of the 128 result rows, so utilisation is bounded
    by Q/128 (F and BD are chosen as multiples of 128 and don't limit).
    This is why the L3 coordinator batches queries (paper: "number of query
    that requires simultaneous processing") before dispatching a block.
    """
    rows = min(q, 128) / 128.0
    cols = min(block_d, 128) / 128.0 if block_d < 128 else 1.0
    depth = min(f, 128) / 128.0 if f < 128 else 1.0
    return rows * cols * depth
