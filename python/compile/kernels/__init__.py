"""Layer-1 Pallas kernels for GAPS relevance scoring.

`bm25` holds the production kernel (tiled BM25F scoring); `ref` holds the
pure-jnp oracle every kernel is validated against at build time.
"""

from . import bm25, ref  # noqa: F401
